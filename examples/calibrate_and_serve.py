"""Calibrate clipping constants, quantize into SPARQLe form, and serve.

The full deployment recipe of the paper:
  1. train (or load) a float model                       — substrate
  2. GLOBAL calibration: sweep (l, h) on calibration data (§3.2, Llama
     recipe) against the sparsity/error tradeoff
  3. LAYERWISE calibration: Algorithm 1 — learn per-layer (l, h) with
     everything frozen (BitNet recipe)
  4. quantize W4A8 + clipping masks -> SparqleLinear served form
  5. serve: prefill + decode on the sub-precision path, report achieved
     MSB4 sparsity and the accelerator-level win

Run:  PYTHONPATH=src python examples/calibrate_and_serve.py  (~3 min CPU)
"""
import jax
import jax.numpy as jnp

from repro.core.clipping import (apply_clipping, global_calibrate,
                                 importance_mask_tile_aligned,
                                 init_clip_params, learn_clipping_constants,
                                 soft_clipping)
from repro.core.qlinear import quantize_model_params
from repro.core.quantize import quantize_activations
from repro.core.sparqle import subprecision_sparsity
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import model as M
from repro.models.registry import get_config
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema

cfg = get_config("granite-8b", smoke=True)
params = init_params(build_schema(cfg), jax.random.PRNGKey(0))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
cal = jnp.asarray(data.batch_at(0)["tokens"])

# ---- step 2: global (l, h) sweep on a calibration batch ------------------
hidden = M.forward_hidden(cfg, params, {"tokens": cal})
q8 = quantize_activations(hidden.reshape(-1, hidden.shape[-1]),
                          bits=8, per_token=True).q
w0 = params["stages"]["s0"]["p0"]["w_gate"][0]
mask = importance_mask_tile_aligned(w0, 50.0, 16)


def eval_fn(l, h):
    qc = apply_clipping(q8, mask, l, h)
    mse = float(jnp.mean((qc - q8).astype(jnp.float32) ** 2))
    return mse, float(subprecision_sparsity(qc))


best = global_calibrate(eval_fn)
print(f"global calibration  : l={best.l} h={best.h} "
      f"sparsity={best.sparsity*100:.1f}% err={best.error:.3f}")

# ---- step 3: Algorithm 1 — layerwise learned constants -------------------
maskf = mask.astype(jnp.float32)


def apply_clip(cp, batch):
    y, m = soft_clipping(batch, maskf, cp["l"][0], cp["h"][0], tau=4.0)
    return y * 0.01, jnp.mean(m)


def apply_base(batch):
    return batch.astype(jnp.float32) * 0.01


cp, hist = learn_clipping_constants(
    apply_clip, apply_base, q8.reshape(4, -1, q8.shape[-1]),
    init_clip_params(1, l0=float(best.l), h0=float(best.h)),
    epochs=23, lr=1.0, alpha=0.5)
print(f"Algorithm 1 (23 it) : l={float(cp['l'][0]):.1f} "
      f"h={float(cp['h'][0]):.1f} (learned, weights frozen)")

# ---- steps 4-5: quantize + serve -----------------------------------------
qparams = quantize_model_params(
    params, w_bits=cfg.w_bits, k_percent=50.0,
    clip_l=float(cp["l"][0]), clip_h=float(cp["h"][0]), tile_k=16)

B, P, GEN = 2, 32, 8
prompts = jnp.asarray(data.batch_at(7)["tokens"])[:B, :P]
prefill = jax.jit(S.make_serve_prefill(cfg, P + GEN))
decode = jax.jit(S.make_serve_decode(cfg))
tok, cache = prefill(qparams, {"tokens": prompts})
outs = [tok]
for i in range(GEN - 1):
    tok, cache = decode(qparams, cache, tok,
                        jnp.full((B,), P + i, jnp.int32))
    outs.append(tok)
gen = jnp.stack(outs, 1)
print(f"served              : {gen.shape} tokens on the SPARQLe W4A8 path")
print(f"generated tokens[0] : {list(map(int, gen[0]))}")
