"""Train a ~100M-param LM for a few hundred steps with full fault tolerance.

Demonstrates the production training substrate end-to-end on CPU:
synthetic packed data, microbatched AdamW, async checkpointing, an
injected mid-run failure with automatic restore+replay, and a final
resume-from-checkpoint — the exact machinery `launch/train.py` runs at
pod scale.

Run:  PYTHONPATH=src python examples/train_with_failover.py
      (--steps 300 --d-model 512 for the full ~100M config; the default
       keeps CI-sized wall time)
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.fault import FaultInjector, RestartableLoop
from repro.launch import steps as S
from repro.models.schema import init_params, param_count
from repro.models.schema_builder import build_schema
from repro.optim.adamw import OptConfig, init_opt_state

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

cfg = ModelConfig(
    name="demo-lm", family="transformer", n_layers=args.layers,
    d_model=args.d_model, n_heads=8, n_kv_heads=4,
    d_ff=int(2.75 * args.d_model), vocab=2048)
schema = build_schema(cfg)
print(f"model: {param_count(schema)/1e6:.1f}M params")

ocfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
knobs = S.TrainKnobs(microbatch=args.batch // 2, ce_chunk=64)
step_fn = jax.jit(S.make_train_step(cfg, ocfg, knobs), donate_argnums=0)
params = init_params(schema, jax.random.PRNGKey(0))
state = S.TrainState(params, init_opt_state(params, ocfg))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch))

ckdir = tempfile.mkdtemp(prefix="repro_failover_")
losses = []


def logged(st, batch):
    st, m = step_fn(st, batch)
    losses.append(float(m["loss"]))
    if len(losses) % 10 == 0:
        print(f"  step {len(losses):4d} loss {losses[-1]:.4f}")
    return st, m


def make_batch(i):
    return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}


fail_at = args.steps // 2
print(f"training {args.steps} steps; injecting a failure at step "
      f"{fail_at} (checkpoint every 20, async)")
loop = RestartableLoop(
    logged, make_batch, ckdir, ckpt_every=20, async_ckpt=True,
    injector=FaultInjector(plan={fail_at: "fail"}))
state, _ = loop.run(state, 0, args.steps)
print(f"loop report: {loop.report}")
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"(replayed steps included)")

# resume-from-checkpoint path (what --resume auto does)
latest = store.latest_step(ckdir)
state2 = store.restore(ckdir, latest, state)
print(f"restored step {latest}; params bit-identical: "
      f"{bool(jnp.all(jax.tree_util.tree_leaves(state2.params)[0] == jax.tree_util.tree_leaves(state.params)[0]))}")
shutil.rmtree(ckdir)
