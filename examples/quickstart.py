"""Quickstart: the SPARQLe idea end-to-end in ~60 lines.

1. Decompose an int8 activation tensor into LSB4 / MSB4 / PBM (paper §3.1)
2. Enhance MSB4 sparsity with column-importance clipping (paper §3.2)
3. Run the dual-pass matmul — exact vs the dense int8 baseline (§3.3)
4. Predict the accelerator-level latency/energy win at that sparsity (§4)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clipping import apply_clipping, importance_mask_tile_aligned
from repro.core.costmodel import HardwareConfig, LinearShape, linear_cost
from repro.core.quantize import quantize_activations, quantize_weights
from repro.core.sparqle import (compression_percent, encode,
                                ops_reduction_percent, subprecision_sparsity,
                                tile_population)
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.sparqle_matmul import sparqle_matmul

key = jax.random.PRNGKey(0)

# --- a "realistic" activation matrix: near-zero bulk + outlier columns ---
x = jax.random.laplace(key, (256, 512)) * 4.0
x = x.at[:, ::17].mul(25.0)                       # outlier channels
w = jax.random.normal(jax.random.PRNGKey(1), (512, 256)) * 0.05

qa = quantize_activations(x, bits=8, per_token=True)
qw = quantize_weights(w, bits=4, axis=0)

s0 = float(subprecision_sparsity(qa.q))
print(f"natural MSB4 sparsity            : {s0*100:5.1f}%")
print(f"  -> Eq.1 compression            : {float(compression_percent(s0)):5.1f}% bytes saved")
print(f"  -> Eq.2 ops reduction          : {float(ops_reduction_percent(s0)):5.1f}% int4 MACs skipped")

# --- §3.2: clip the 50% least-important columns (tile-aligned for TPU) ---
# aggressive bounds fully clear the masked columns — maximum sparsity end
# of the accuracy/efficiency knob (moderate bounds like l=-16,h=31 trade
# less error for fewer cleared tiles; see benchmarks/bench_k_sweep.py)
mask = importance_mask_tile_aligned(w, 50.0, tile_k=128)
q_clip = apply_clipping(qa.q, mask, l=-128, h=127)
s1 = float(subprecision_sparsity(q_clip))
print(f"after clipping (k=50, full range): {s1*100:5.1f}%")

# --- §3.3: dual-pass kernel == dense baseline, bit-exact ------------------
act = encode(q_clip)
pop = tile_population(act.pbm, 128, 128)
asc = qa.scale.reshape(-1, 1)
wsc = qw.scale.reshape(1, -1)
out_sparqle = sparqle_matmul(act.lsb4, act.msb4, pop, qw.q, asc, wsc)
out_dense = quant_matmul(q_clip, qw.q, asc, wsc)
np.testing.assert_allclose(np.asarray(out_sparqle), np.asarray(out_dense),
                           rtol=1e-6)
skipped = float((pop == 0).mean())
print(f"dual-pass == dense int8 matmul   : exact "
      f"({skipped*100:.0f}% of MSB4 tiles skipped on the MXU)")

# --- §4: what the hybrid accelerator buys at this sparsity ---------------
hw = HardwareConfig()
shape = LinearShape("demo", 2048, 4096, 11008, w_bits=4, s=s1)
base = linear_cost(shape, hw, sparqle=False)
spq = linear_cost(shape, hw, sparqle=True)
print(f"accelerator model @ s={s1:.2f}      : "
      f"latency -{(1-spq.cycles/base.cycles)*100:.1f}%, "
      f"energy -{(1-spq.energy_pj/base.energy_pj)*100:.1f}%")
