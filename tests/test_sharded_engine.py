"""Mesh-sharded serving engine: stream equivalence + pool shard state.

Acceptance criterion of the TP serving work (docs/sharding.md): on a
forced multi-CPU-device mesh, the sharded ``Engine`` and
``SpeculativeEngine`` greedy token streams are BYTE-identical to the
single-device engine on transformer and MoE configs. These tests run in
the CI `test-multidevice` lane (8 forced host devices) and skip cleanly
on a single device via the `mesh` fixture.

The pool shard-consistency property test and the validation-error tests
are host-only and run everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.serving import (Engine, PagedKVPool, PoolConfig, SamplingParams,
                           SchedulerConfig, SpecConfig, SpeculativeEngine)

# 2-way-TP-friendly transformer (n_kv_heads=2) and a 4-way variant
CFG = ModelConfig(name="tiny-serve", family="transformer", n_layers=2,
                  d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                  d_ff=64, vocab=128, dtype="float32")
CFG_TP4 = ModelConfig(name="tiny-serve-tp4", family="transformer",
                      n_layers=2, d_model=32, n_heads=8, n_kv_heads=4,
                      head_dim=4, d_ff=64, vocab=128, dtype="float32")
CFG_MOE = ModelConfig(name="tiny-moe-serve", family="moe", n_layers=4,
                      d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_ff=64, vocab=64, dtype="float32", n_experts=4,
                      top_k=2, moe_every=2, moe_d_ff=32,
                      router_type="softmax")


def _qparams(cfg, seed=0):
    fp = init_params(build_schema(cfg), jax.random.PRNGKey(seed))
    return quantize_model_params(
        fp, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)


def _prompts(cfg, seed=0, lens=(9, 13, 7, 11)):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, size=n).tolist() for n in lens]


def _run(cfg, qp, prompts, mesh=None, gamma=0, gen=5):
    kw = dict(pool_config=PoolConfig(n_pages=32, page_size=4),
              sched_config=SchedulerConfig(max_decode_batch=4,
                                           token_budget=64,
                                           prefill_chunk=8,
                                           max_pages_per_seq=8),
              mesh=mesh)
    eng = (SpeculativeEngine(cfg, qp, spec=SpecConfig(gamma=gamma), **kw)
           if gamma else Engine(cfg, qp, **kw))
    handles = [eng.submit(p, SamplingParams(max_new_tokens=gen))
               for p in prompts]
    eng.run()
    return [h.out_tokens for h in handles], eng


# ---------------------------------------------------------------------------
# engine stream equivalence (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg,shape", [
    (CFG, (1, 2)),            # pure 2-way tensor parallelism
    (CFG, (2, 2)),            # data x model
    (CFG_TP4, (1, 4)),        # 4-way tensor parallelism
    (CFG_TP4, (2, 4)),        # the CI-lane mesh shape
    (CFG_MOE, (1, 2)),        # MoE: expert-mlp sharding
    (CFG_MOE, (2, 2)),        # MoE under a data-sharded decode batch
], ids=["tf-1x2", "tf-2x2", "tf-1x4", "tf-2x4", "moe-1x2", "moe-2x2"])
def test_engine_sharded_stream_matches_single_device(mesh, cfg, shape):
    m = mesh(data=shape[0], model=shape[1])
    qp = _qparams(cfg)
    prompts = _prompts(cfg)
    ref, ref_eng = _run(cfg, qp, prompts)
    got, eng = _run(cfg, qp, prompts, mesh=m)
    assert got == ref
    # telemetry rides along bit-exact too (the hidden stream is
    # replicated over model shards and exact by the psum argument)
    assert eng.steps == ref_eng.steps
    assert eng.pool.evictions == ref_eng.pool.evictions


@pytest.mark.parametrize("cfg,seed", [(CFG, 0), (CFG_MOE, 1)],
                         ids=["transformer", "moe"])
def test_spec_engine_sharded_stream_matches_single_device(mesh, cfg, seed):
    """Sharded speculative engine (draft + batched verify both inside
    shard_map) emits the same greedy bytes as the single-device BASE
    engine — speculation and sharding are both exactness-preserving."""
    m = mesh(data=2, model=2)
    qp = _qparams(cfg, seed=seed)
    prompts = _prompts(cfg, seed=seed)
    ref, _ = _run(cfg, qp, prompts)
    got, eng = _run(cfg, qp, prompts, mesh=m, gamma=2)
    assert got == ref
    agg = eng.aggregate_stats()
    assert agg["spec_gamma"] == 2 and agg["steps"] > 0


def test_decode_step_sharded_logits_bitexact(mesh):
    """Step-level check (no engine): one sharded decode_step_paged call
    against the paged pool reproduces logits, pool writes and telemetry
    of the unsharded call exactly."""
    from repro.distributed import tp
    from repro.launch import steps as S
    m = mesh(model=2)
    cfg = CFG
    qp = _qparams(cfg)
    pool = PagedKVPool(cfg, PoolConfig(n_pages=8, page_size=4))
    pool.allocate(2, owner="a")
    token = jnp.asarray([3, 0], jnp.int32)
    pos = jnp.asarray([4, 0], jnp.int32)
    tables = jnp.asarray([[1, 2], [0, 0]], jnp.int32)

    ref_fn = S.make_engine_decode(cfg)
    ref_logits, ref_pool, ref_tel = ref_fn(qp, pool.state, token, pos,
                                           tables)

    pspecs = tp.param_pspecs(qp)
    poolspecs = tp.pool_pspecs(cfg, pool.pool_cfg, m)
    sh_fn = S.make_engine_decode(cfg, mesh=m, param_specs=pspecs,
                                 pool_specs=poolspecs)
    qp_s = tp.device_put_tree(qp, pspecs, m)
    state_s = tp.device_put_tree(
        PagedKVPool(cfg, PoolConfig(n_pages=8, page_size=4)).state,
        poolspecs, m)
    got_logits, got_pool, got_tel = sh_fn(qp_s, state_s, token, pos,
                                          tables)
    np.testing.assert_array_equal(np.asarray(got_logits),
                                  np.asarray(ref_logits))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        got_pool, ref_pool)
    for k in ref_tel:
        np.testing.assert_array_equal(np.asarray(got_tel[k]),
                                      np.asarray(ref_tel[k]))


# ---------------------------------------------------------------------------
# pool shard consistency (host-only; runs on any device count)
# ---------------------------------------------------------------------------

@pytest.mark.property
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
def test_pool_shard_consistency_property(seed, n_shards):
    """Drive two pools (the 'lock-step replicas' of the model-axis
    shards) through one random allocate/evict/truncate/release sequence:
    every operation must return identical page ids on both — the
    invariant that lets one block table index every device shard — and
    per-shard state must stay coherent (disjoint local free lists +
    owned pages covering each sub-pool, owners pinned to one shard,
    local null page never handed out)."""
    rng = np.random.RandomState(seed)
    cfgp = PoolConfig(n_pages=16, page_size=4)
    pools = [PagedKVPool(CFG, cfgp, n_shards=n_shards) for _ in range(2)]
    owners: dict = {}
    for _ in range(40):
        op = rng.randint(4)
        if op == 0:                                       # allocate
            owner = int(rng.randint(6))
            shard = owners.get(owner, int(rng.randint(n_shards)))
            n = int(rng.randint(1, 4))
            got = [p.allocate(n, owner, shard=shard) for p in pools]
            assert got[0] == got[1]                       # lock-step
            if got[0]:
                owners[owner] = shard
        elif op == 1:                                     # truncate
            owner = int(rng.randint(6))
            tok = int(rng.randint(0, 20))
            got = [p.truncate(owner, tok) for p in pools]
            assert got[0] == got[1]
            if owner in owners and not pools[0].pages_of(owner):
                owners.pop(owner)
        elif op == 2:                                     # evict
            owner = int(rng.randint(6))
            got = [p.evict(owner) for p in pools]
            assert got[0] == got[1]
            owners.pop(owner, None)
        else:                                             # release
            owner = int(rng.randint(6))
            got = [p.release(owner) for p in pools]
            assert got[0] == got[1]
            owners.pop(owner, None)
        p = pools[0]
        per_shard = p.pages_per_shard
        seen = [set() for _ in range(n_shards)]
        for owner, pages in p._owned.items():
            shard = p.shard_of(owner)
            assert owners[owner] == shard                 # pinned
            for pg in pages:
                assert 1 <= pg < per_shard                # local, non-null
                assert pg not in seen[shard]              # no double-grant
                seen[shard].add(pg)
        for s in range(n_shards):
            frees = set(p._free[s])
            assert 0 not in frees                         # null reserved
            assert not (frees & seen[s])                  # disjoint
            assert frees | seen[s] == set(range(1, per_shard))  # complete
        assert pools[0].num_free == pools[1].num_free


def test_pool_shard_capacity_and_validation():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4), n_shards=2)
    assert pool.pages_per_shard == 4
    assert pool.n_usable_pages == 6          # one null page PER shard
    assert pool.usable_pages_per_shard == 3
    a = pool.allocate(3, "a", shard=0)
    assert a is not None and pool.allocate(1, "x", shard=0) is None
    assert pool.allocate(1, "b", shard=1) is not None   # other shard fine
    with pytest.raises(ValueError):          # owners pin to one shard
        pool.allocate(1, "a", shard=1)
    with pytest.raises(ValueError):          # n_pages must divide
        PagedKVPool(CFG, PoolConfig(n_pages=9, page_size=4), n_shards=2)
    with pytest.raises(ValueError):          # >= 2 pages per shard
        PagedKVPool(CFG, PoolConfig(n_pages=4, page_size=4), n_shards=4)


def test_engine_mesh_validation_lists_indivisible_dims(mesh):
    """Engine(mesh=...) must reject configs the model axis cannot divide,
    naming every offending dimension."""
    m = mesh(data=1, model=4)
    bad = CFG                                # n_kv_heads=2 % 4 != 0
    with pytest.raises(ValueError, match="n_kv_heads"):
        Engine(bad, _qparams(bad), mesh=m)


def test_engine_mesh_rejects_indivisible_decode_batch(mesh):
    m = mesh(data=2, model=1)
    with pytest.raises(ValueError, match="max_decode_batch"):
        Engine(CFG, _qparams(CFG),
               pool_config=PoolConfig(n_pages=8, page_size=4),
               sched_config=SchedulerConfig(max_decode_batch=3),
               mesh=m)
