"""Packed sub-precision wire format: exact inverses, layout semantics,
measured-vs-Eq.1 byte accounting (docs/format.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.core import packing as P
from repro.core.sparqle import encode, encoded_bytes, subprecision_sparsity


def test_roundtrip_all_int8_values():
    """decode_packed(encode_packed(x)) is the identity on every
    representable int8 value."""
    x = jnp.arange(-128, 128, dtype=jnp.int8).reshape(8, 32)
    p = P.encode_packed(x)
    np.testing.assert_array_equal(np.asarray(P.decode_packed(p)),
                                  np.asarray(x))


@pytest.mark.parametrize("shape", [(3, 7), (2, 31), (4, 32), (7, 129)])
def test_roundtrip_odd_and_tile_edge_shapes(shape):
    """K-padding is invisible: odd K, just-below/above word boundaries."""
    x = jax.random.randint(jax.random.PRNGKey(hash(shape) % 2**31), shape,
                           -128, 128, dtype=jnp.int8)
    p = P.encode_packed(x)
    assert p.lsb4.shape[-1] * 2 == P.pad_k(shape[1])
    np.testing.assert_array_equal(np.asarray(P.decode_packed(p)),
                                  np.asarray(x))


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([(4, 64), (3, 50)]))
def test_roundtrip_random(seed, shape):
    x = jax.random.randint(jax.random.PRNGKey(seed), shape, -128, 128,
                           dtype=jnp.int8)
    assert (P.decode_packed(P.encode_packed(x)) == x).all()


def test_unpack_planes_matches_plane_codec():
    """The packed format and the dense-plane codec describe the same
    decomposition: unpack_planes == sparqle.encode on every value."""
    x = jnp.arange(-128, 128, dtype=jnp.int8).reshape(4, 64)
    a = P.unpack_planes(P.encode_packed(x))
    ref = encode(x)
    np.testing.assert_array_equal(np.asarray(a.lsb4), np.asarray(ref.lsb4))
    np.testing.assert_array_equal(np.asarray(a.msb4), np.asarray(ref.msb4))
    np.testing.assert_array_equal(np.asarray(a.pbm), np.asarray(ref.pbm))


def test_nibble_pair_layout():
    """Byte j holds column 2j in its low nibble, 2j+1 in its high nibble."""
    x = jnp.asarray([[0x1, 0x2, 0xF, 0x0]], jnp.int8)   # lsb-only values
    packed = P.pack_nibbles(x)
    np.testing.assert_array_equal(np.asarray(packed).astype(np.uint8),
                                  [[0x21, 0x0F]])
    np.testing.assert_array_equal(
        np.asarray(P.unpack_nibbles(packed, signed=False)), np.asarray(x))


def test_signed_nibble_unpack_sign_extends():
    nib = jnp.asarray([[-8, 7, -1, 0]], jnp.int8)
    back = P.unpack_nibbles(P.pack_nibbles(nib), signed=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(nib))


def test_pbm_word_layout_little_endian():
    """Bit i of word w is the PBM of column 32*w + i."""
    pbm = jnp.zeros((1, 64), bool).at[0, 0].set(True).at[0, 33].set(True)
    words = np.asarray(P.pack_pbm(pbm))
    assert words.dtype == np.uint32
    np.testing.assert_array_equal(words, [[1, 2]])
    np.testing.assert_array_equal(np.asarray(P.unpack_pbm(P.pack_pbm(pbm),
                                                          64)),
                                  np.asarray(pbm))


def test_msb_stream_is_bitmap_indexed_and_compact():
    """Stream nibble r belongs to the column of the r-th set PBM bit, in
    column order; unused container nibbles stay zero."""
    x = jnp.zeros((1, 32), jnp.int8)
    x = x.at[0, 3].set(0x50).at[0, 10].set(-128).at[0, 20].set(0x20)
    # msb4 values: col3 -> 5, col10 -> -8, col20 -> 2
    p = P.encode_packed(x)
    np.testing.assert_array_equal(np.asarray(p.msb_count), [3])
    stream = np.asarray(P.unpack_nibbles(p.msb_stream, signed=True))[0]
    np.testing.assert_array_equal(stream[:3], [5, -8, 2])
    assert (stream[3:] == 0).all()
    np.testing.assert_array_equal(np.asarray(P.decode_packed(p)),
                                  np.asarray(x))


@pytest.mark.parametrize("s", [0.0, 0.3, 0.7, 1.0])
def test_wire_bytes_matches_eq1_within_slack(s):
    """Measured wire bytes == Eq.1 prediction up to the PBM-word and
    per-row stream rounding slack (< 2 % at these shapes)."""
    key = jax.random.PRNGKey(int(s * 100))
    k1, k2, k3 = jax.random.split(key, 3)
    small = jax.random.randint(k1, (256, 256), 0, 16, dtype=jnp.int8)
    big = jax.random.randint(k2, (256, 256), -128, 128, dtype=jnp.int8)
    x = jnp.where(jax.random.uniform(k3, (256, 256)) < s, small, big)
    x = x.astype(jnp.int8)
    s_obs = float(subprecision_sparsity(x))
    measured = int(P.encode_packed(x).wire_bytes())
    predicted = encoded_bytes(x.shape, s_obs)
    assert abs(measured - predicted) / predicted < 0.02, (measured,
                                                         predicted)


def test_wire_bytes_measured_rows_consistent_with_codec():
    x = jax.random.randint(jax.random.PRNGKey(9), (33, 100), -128, 128,
                           dtype=jnp.int8)
    rows = P.measured_wire_bytes_rows(x)
    assert rows.shape == (33,)
    assert int(rows.sum()) == int(P.encode_packed(x).wire_bytes())


def test_wire_bytes_bounds():
    """Fully sub-precision-sparse rows pay LSB+PBM only; fully dense rows
    pay the full MSB plane too — and both stay below dense int8 + PBM."""
    m, k = 64, 256
    sparse = P.encode_packed(jnp.zeros((m, k), jnp.int8))
    dense = P.encode_packed(jnp.full((m, k), 127, jnp.int8))
    assert int(sparse.wire_bytes()) == m * (k // 2 + k // 8)
    assert int(dense.wire_bytes()) == m * (k // 2 + k // 8 + k // 2)
    assert int(dense.wire_bytes()) < dense.dense_bytes() + m * k // 8 + 1


def test_container_vs_wire_accounting():
    """The device container is worst-case sized; wire_bytes is measured
    and data-dependent."""
    x = jnp.zeros((8, 64), jnp.int8).at[0, 0].set(127)
    p = P.encode_packed(x)
    assert int(p.wire_bytes()) < p.container_bytes()
    # exactly one nonzero MSB nibble -> one stream byte in total
    assert int(p.wire_bytes()) == 8 * (32 + 8) + 1


def test_encode_packed_jittable():
    x = jax.random.randint(jax.random.PRNGKey(0), (16, 96), -128, 128,
                           dtype=jnp.int8)
    p = jax.jit(P.encode_packed)(x)
    np.testing.assert_array_equal(np.asarray(jax.jit(P.decode_packed)(p)),
                                  np.asarray(x))


# ---------------------------------------------------------------------------
# parameterized plane codec (pack_plane / unpack_plane, docs/format.md)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", P.PLANE_WIDTHS)
def test_plane_roundtrip_exhaustive_bytes(width):
    """Every possible packed byte survives unpack -> pack at every width
    (and therefore every field-value combination round-trips): the codec
    pair is a bijection between bytes and field tuples."""
    b = jnp.arange(-128, 128, dtype=jnp.int8).reshape(16, 16)
    for signed in (True, False):
        fields = P.unpack_plane(b, width=width, signed=signed)
        assert fields.shape == (16, 16 * (8 // width))
        np.testing.assert_array_equal(
            np.asarray(P.pack_plane(fields, width=width)), np.asarray(b))


def test_plane_roundtrip_exhaustive_int2_values():
    """k=2 mirror of test_roundtrip_all_int8_values: every signed int2
    value in every one of the four byte positions round-trips exactly."""
    import itertools
    combos = np.asarray(list(itertools.product(range(-2, 2), repeat=4)),
                        np.int8)                     # (256, 4): all tuples
    packed = P.pack_plane(jnp.asarray(combos), width=2)
    assert packed.shape == (256, 1)
    np.testing.assert_array_equal(
        np.asarray(P.unpack_plane(packed, width=2, signed=True)), combos)
    # unsigned: [0, 3] in every position
    u = np.asarray(list(itertools.product(range(4), repeat=4)), np.int8)
    pu = P.pack_plane(jnp.asarray(u), width=2)
    np.testing.assert_array_equal(
        np.asarray(P.unpack_plane(pu, width=2, signed=False)), u)


def test_plane_width2_byte_layout_little_endian():
    """Field i of a byte lives at bits [2i, 2i+2): the 2-bit analogue of
    test_nibble_pair_layout's low-nibble-first rule."""
    x = jnp.asarray([[1, -2, 0, -1]], jnp.int8)
    packed = np.asarray(P.pack_plane(x, width=2)).astype(np.uint8)
    # 0b01 | 0b10<<2 | 0b00<<4 | 0b11<<6 == 0xC9
    np.testing.assert_array_equal(packed, [[0xC9]])


def test_plane_width4_is_the_nibble_codec():
    """pack_nibbles/unpack_nibbles are the width=4 specialization."""
    x = jnp.arange(-8, 8, dtype=jnp.int8).reshape(2, 8)
    np.testing.assert_array_equal(np.asarray(P.pack_plane(x, width=4)),
                                  np.asarray(P.pack_nibbles(x)))
    p = P.pack_nibbles(x)
    for signed in (True, False):
        np.testing.assert_array_equal(
            np.asarray(P.unpack_plane(p, width=4, signed=signed)),
            np.asarray(P.unpack_nibbles(p, signed=signed)))


def test_plane_width8_is_identity():
    x = jnp.arange(-128, 128, dtype=jnp.int8).reshape(4, 64)
    np.testing.assert_array_equal(np.asarray(P.pack_plane(x, width=8)),
                                  np.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(P.unpack_plane(x, width=8, signed=True)), np.asarray(x))


def test_plane_invalid_width_rejected():
    x = jnp.zeros((1, 8), jnp.int8)
    for width in (0, 3, 5, 16):
        with pytest.raises(ValueError):
            P.pack_plane(x, width=width)
        with pytest.raises(ValueError):
            P.unpack_plane(x, width=width, signed=True)
    with pytest.raises(ValueError):
        P.predicted_wire_bytes(8, 0.5, width=3)


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4, 8]))
def test_plane_roundtrip_random(seed, width):
    half = 1 << (width - 1)
    x = jax.random.randint(jax.random.PRNGKey(seed), (5, 24), -half, half,
                           dtype=jnp.int8)
    p = P.pack_plane(x, width=width)
    assert p.shape == (5, 24 * width // 8)
    assert (P.unpack_plane(p, width=width, signed=True) == x).all()


def test_predicted_wire_bytes_width4_matches_eq1():
    """The generalized prediction at width=4 IS the paper's Eq. 1."""
    for s in (0.0, 0.25, 0.8, 1.0):
        assert P.predicted_wire_bytes(64 * 256, s) == pytest.approx(
            encoded_bytes((64, 256), s))
    # width=8 degenerates to dense int8 + the bitmap
    assert P.predicted_wire_bytes(100, 0.7, width=8) == pytest.approx(
        100 * (1 + 1 / 8))


def test_planes_packed_roundtrip():
    """Kernel operand form: both packed planes unpack to the reference
    decomposition."""
    x = jax.random.randint(jax.random.PRNGKey(4), (8, 128), -128, 128,
                           dtype=jnp.int8)
    lsbp, msbp = P.planes_packed(P.encode_packed(x))
    ref = encode(x)
    np.testing.assert_array_equal(
        np.asarray(P.unpack_nibbles(lsbp, signed=False)),
        np.asarray(ref.lsb4))
    np.testing.assert_array_equal(
        np.asarray(P.unpack_nibbles(msbp, signed=True)),
        np.asarray(ref.msb4))
