"""Tests for the static-analysis subsystem (src/repro/analysis/).

Per-rule unit tests run the AST rules on synthetic source trees and the
jaxpr rules on toy traced functions — each rule has a deliberately
broken fixture proven to fail and a clean fixture proven to pass. The
self-check tests then assert the real repo is green under the committed
allowlist (the same gate CI runs via ``python -m repro.analysis
--check``).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import RULES, VERSION, ruleset_hash
from repro.analysis import astlint, jaxprcheck
from repro.analysis.findings import (ALLOWLIST_PATH, Allowlist, Finding,
                                     apply_allowlist)
from repro.analysis.jaxprcheck import TracedStep

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _lint_tree(tmp_path, files, docs=""):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    docs_path = ""
    if docs:
        docs_path = str(tmp_path / "observability.md")
        (tmp_path / "observability.md").write_text(docs)
    return astlint.run(str(tmp_path), docs_path=docs_path)


def _rules(findings):
    return sorted({f.rule_id for f in findings})


# ------------------------------------------------------------- SPL001

def test_spl001_print_in_jitted_fn(tmp_path):
    fs = _lint_tree(tmp_path, {"mod.py": """
        import jax

        @jax.jit
        def step(x):
            print("debug", x)
            return x + 1
    """})
    assert any(f.rule_id == "SPL001" and "print" in f.message
               for f in fs)


def test_spl001_time_via_reachability(tmp_path):
    # the side effect sits in a helper reached through a call chain and
    # a higher-order reference (lax.scan body), not in the root itself
    fs = _lint_tree(tmp_path, {"mod.py": """
        import time
        import jax

        def helper(x):
            t0 = time.perf_counter()
            return x * t0

        def body(c, x):
            return helper(c), x

        @jax.jit
        def step(x):
            return jax.lax.scan(body, x, None, length=3)
    """})
    assert any(f.rule_id == "SPL001" and "perf_counter" in f.message
               for f in fs)


def test_spl001_obs_calls_flagged(tmp_path):
    fs = _lint_tree(tmp_path, {"mod.py": """
        import jax

        @jax.jit
        def step(x, tracer, m):
            tracer.span("oops")
            m.inc(1)
            return x
    """})
    msgs = [f.message for f in fs if f.rule_id == "SPL001"]
    assert any("tracer.span" in m for m in msgs)
    assert any(".inc()" in m for m in msgs)


def test_spl001_clean_and_host_side_untouched(tmp_path):
    # a host-side (non-root, unreachable) function may print/time freely
    fs = _lint_tree(tmp_path, {"mod.py": """
        import time
        import jax

        @jax.jit
        def step(x):
            return x + 1

        def host_loop(x):
            t0 = time.perf_counter()
            print("host", t0)
            return step(x)
    """})
    assert not [f for f in fs if f.rule_id == "SPL001"]


# ------------------------------------------------------------- SPL002

def test_spl002_device_op_in_host_module(tmp_path):
    fs = _lint_tree(tmp_path, {"serving/scheduler.py": """
        import jax.numpy as jnp

        def admit(n):
            return jnp.zeros((n,))
    """})
    assert any(f.rule_id == "SPL002" and "jnp.zeros" in f.message
               for f in fs)


def test_spl002_dtype_attrs_and_other_modules_ok(tmp_path):
    fs = _lint_tree(tmp_path, {
        # dtype attribute access is not a device op
        "serving/scheduler.py": """
            import jax.numpy as jnp
            DTYPE = jnp.int8
        """,
        # device ops outside host-only modules are fine
        "models/net.py": """
            import jax.numpy as jnp

            def f(x):
                return jnp.tanh(x)
        """})
    assert not [f for f in fs if f.rule_id == "SPL002"]


# ------------------------------------------------------------- SPL003

def test_spl003_tracer_leaks(tmp_path):
    fs = _lint_tree(tmp_path, {"mod.py": """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            if jnp.any(x > 0):
                y = float(jnp.max(x))
            else:
                y = x.sum().item()
            return y
    """})
    msgs = [f.message for f in fs if f.rule_id == "SPL003"]
    assert any(".item()" in m for m in msgs)
    assert any("float()" in m for m in msgs)
    assert any("control flow" in m for m in msgs)


def test_spl003_static_python_ok(tmp_path):
    fs = _lint_tree(tmp_path, {"mod.py": """
        import jax

        @jax.jit
        def step(x, n: int = 4):
            if n > 2:                 # static config, not traced
                x = x * float(n)      # float() of a python int
            return x
    """})
    assert not [f for f in fs if f.rule_id == "SPL003"]


# ------------------------------------------------------------- SPL004

_DOCS = "catalog: `good_total` and `depth_now` are documented.\n"


def test_spl004_naming_and_catalog(tmp_path):
    fs = _lint_tree(tmp_path, {"eng.py": """
        def setup(r):
            a = r.counter("Bad-Name", "x", unit="1")
            b = r.counter("missing_suffix", "x", unit="1")
            c = r.gauge("undocumented_depth", "x", unit="1")
            return a, b, c
    """}, docs=_DOCS)
    msgs = [f.message for f in fs if f.rule_id == "SPL004"]
    assert any("violates" in m for m in msgs)
    assert any("_total" in m for m in msgs)
    assert any("not cataloged" in m for m in msgs)


def test_spl004_documented_metrics_pass(tmp_path):
    fs = _lint_tree(tmp_path, {"eng.py": """
        def setup(r):
            return (r.counter("good_total", "x", unit="1"),
                    r.gauge("depth_now", "x", unit="1"))
    """}, docs=_DOCS)
    assert not [f for f in fs if f.rule_id == "SPL004"]


# ----------------------------------------------------- jaxpr toy rules

def _toy_step(fn, *args, kind="decode", mesh=False, name=None):
    return TracedStep(name or f"{kind}/toy/{'mesh' if mesh else 'single'}",
                      kind, "transformer", mesh, jax.make_jaxpr(fn)(*args))


def _shmap(fn, n_out=1):
    """Wrap fn in a 1x1 shard_map so collectives trace as primitives."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    outs = P() if n_out == 1 else tuple(P() for _ in range(n_out))
    return shard_map(fn, mesh=mesh, in_specs=P(), out_specs=outs,
                     check_rep=False)


def test_jxp002_row_psum_discipline():
    x = jnp.ones((4, 8), jnp.float32)

    def good(x):
        acc = (x.astype(jnp.int8) @ jnp.ones((8, 8), jnp.int8)
               ).astype(jnp.int32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)), "model")
        acc = jax.lax.psum(acc, "model")
        return acc.astype(jnp.float32) * scale

    out = []
    jaxprcheck.check_row_psum(_toy_step(_shmap(good), x, mesh=True), out)
    # 1 psum + 1 pmax pair up, but a transformer decode expects 2 sites
    assert [f.key for f in out] == ["decode:row-site-count"]

    def float_psum(x):
        acc = x @ jnp.ones((8, 8), jnp.float32)
        return jax.lax.psum(acc, "model")

    out = []
    jaxprcheck.check_row_psum(
        _toy_step(_shmap(float_psum), x, mesh=True), out)
    keys = [f.key for f in out]
    assert "decode:psum:model:float32" in keys       # float accumulator
    assert "decode:psum-pmax-pairing" in keys        # psum without pmax


def test_jxp001_collectives_vs_real_allowlist():
    x = jnp.ones((4, 8), jnp.float32)

    def stray(x):
        acc = (x.astype(jnp.int8) @ jnp.ones((8, 8), jnp.int8)
               ).astype(jnp.int32)
        acc = jax.lax.psum(acc, "model")             # allowlisted shape
        return jax.lax.ppermute(acc.astype(jnp.float32), "data",
                                [(0, 0)])            # stray collective

    out = []
    jaxprcheck.check_collectives(
        _toy_step(_shmap(stray), x, mesh=True), out)
    active, allowed = apply_allowlist(out, Allowlist.load())
    assert [f.key for f in allowed] == ["decode:psum:model:int32"]
    assert [f.key for f in active] == ["decode:ppermute:data:float32"]


def test_jxp003_accumulator_discipline():
    q = jnp.ones((4, 8), jnp.int8)
    w = jnp.ones((8, 8), jnp.int8)

    def good(q, w):
        acc = jax.lax.dot_general(q, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * 0.5

    out = []
    jaxprcheck.check_acc_dtype(_toy_step(good, q, w), out)
    assert out == []

    def float_accum(q, w):
        return jax.lax.dot_general(q, w, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    out = []
    jaxprcheck.check_acc_dtype(_toy_step(float_accum, q, w), out)
    assert [f.key for f in out] == ["decode:float-accum"]

    def narrow_accum(q, w):
        return jax.lax.dot_general(q, w, (((1,), (0,)), ((), ())))

    out = []
    jaxprcheck.check_acc_dtype(_toy_step(narrow_accum, q, w), out)
    assert [f.key for f in out] == ["decode:narrow-accum"]

    def bitcast_touch(q, w):
        acc = jax.lax.dot_general(q, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return jax.lax.bitcast_convert_type(acc, jnp.float32)

    out = []
    jaxprcheck.check_acc_dtype(_toy_step(bitcast_touch, q, w), out)
    assert [f.key for f in out] == ["decode:bitcast_convert_type"]


def _toy_dual_pass(q, w):
    lsb = jnp.bitwise_and(q, jnp.int8(15))
    msb = jax.lax.shift_right_arithmetic(q, jnp.int8(4))
    dims = (((1,), (0,)), ((), ()))
    dense = jax.lax.dot_general(lsb, w, dims,
                                preferred_element_type=jnp.int32)
    sparse = jax.lax.dot_general(msb, w, dims,
                                 preferred_element_type=jnp.int32)
    return dense + sparse * 16


def _toy_lsb_only(q, w):
    lsb = jnp.bitwise_and(q, jnp.int8(15))
    return jax.lax.dot_general(lsb, w, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def test_jxp004_msb_skip_elision():
    q = jnp.ones((4, 8), jnp.int8)
    w = jnp.ones((8, 8), jnp.int8)
    full = _toy_step(_toy_dual_pass, q, w, kind="decode")
    draft = _toy_step(_toy_lsb_only, q, w, kind="draft")

    out = []
    jaxprcheck.check_msb_skip(full, draft, out)
    assert out == []

    # a draft that silently kept the MSB pass must fail both ways
    broken = _toy_step(_toy_dual_pass, q, w, kind="draft")
    out = []
    jaxprcheck.check_msb_skip(full, broken, out)
    keys = [f.key for f in out]
    assert "draft:dot-halving" in keys
    assert "draft:msb-dot" in keys


def test_jxp004_detector_self_check():
    # if the full step stops showing shift-fed dots, the rule must
    # report its own blindness instead of passing vacuously
    q = jnp.ones((4, 8), jnp.int8)
    w = jnp.ones((8, 8), jnp.int8)
    not_dual = _toy_step(_toy_lsb_only, q, w, kind="decode")
    draft = _toy_step(_toy_lsb_only, q, w, kind="draft")
    out = []
    jaxprcheck.check_msb_skip(not_dual, draft, out)
    assert any(f.key == "decode:msb-detector" for f in out)


def test_jxp005_callback_ban():
    def leaky(x):
        jax.debug.print("x = {}", x)
        return x + 1

    out = []
    jaxprcheck.check_callbacks(
        _toy_step(leaky, jnp.ones((2,)), kind="decode"), out)
    assert [f.rule_id for f in out] == ["JXP005"]

    def clean(x):
        return x + 1

    out = []
    jaxprcheck.check_callbacks(
        _toy_step(clean, jnp.ones((2,)), kind="decode"), out)
    assert out == []


# -------------------------------------------------- allowlist plumbing

def test_allowlist_match_and_stale(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text("# comment\n"
                 "JXP001  *:psum:model:int32  the one reduce\n"
                 "SPL002  never/matches.py::*  stale entry\n")
    al = Allowlist.load(str(p))
    f1 = Finding("JXP001", "decode:psum:model:int32", "x", "m")
    f2 = Finding("JXP001", "decode:psum:data:float32", "x", "m")
    active, allowed = apply_allowlist([f1, f2], al)
    assert allowed == [f1] and active == [f2]
    assert f1.allowlisted and f1.allow_reason == "the one reduce"
    assert [e.pattern for e in al.stale_entries()] == \
        ["never/matches.py::*"]


def test_allowlist_requires_reason(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text("JXP001  some:key\n")
    with pytest.raises(ValueError, match="reason"):
        Allowlist.load(str(p))


def test_ruleset_hash_tracks_rules():
    h = ruleset_hash()
    assert len(h) == 16 and h == ruleset_hash()
    assert set(RULES) == {"SPL001", "SPL002", "SPL003", "SPL004",
                          "JXP001", "JXP002", "JXP003", "JXP004",
                          "JXP005"}


def test_provenance_meta_stamps_analyzer():
    sys.path.insert(0, REPO)
    try:
        from benchmarks.common import provenance_meta
        meta = provenance_meta()
    finally:
        sys.path.pop(0)
    assert meta["analyzer_version"] == VERSION
    assert meta["analyzer_ruleset"] == ruleset_hash()


# ------------------------------------------------------- repo self-check

def test_repo_ast_layer_green():
    fs = astlint.run(os.path.join(REPO, "src"),
                     docs_path=os.path.join(REPO, "docs",
                                            "observability.md"))
    active, _ = apply_allowlist(fs, Allowlist.load())
    assert active == [], "\n".join(f.render() for f in active)


def test_repo_msb_skip_contract_fast():
    # the acceptance-critical contract on the REAL traced decode step,
    # transformer single-device only so it stays in the fast lane
    from repro.core.qlinear import quantize_model_params
    from repro.launch import steps as S
    from repro.models.schema import init_params
    from repro.models.schema_builder import build_schema
    from repro.serving.kv_pool import PoolConfig, init_pool_state

    cfg = jaxprcheck.tiny_configs()["transformer"]
    fparams = init_params(build_schema(cfg), jax.random.PRNGKey(0))
    qparams = quantize_model_params(fparams, w_bits=4, tile_k=16)
    pool = init_pool_state(cfg, PoolConfig(n_pages=8, page_size=4))
    args = (qparams, pool, jnp.zeros((2,), jnp.int32),
            jnp.zeros((2,), jnp.int32), jnp.zeros((2, 4), jnp.int32))
    full = TracedStep(
        "decode/transformer/single", "decode", "transformer", False,
        jax.make_jaxpr(S.make_engine_decode(cfg))(*args))
    draft = TracedStep(
        "draft/transformer/single", "draft", "transformer", False,
        jax.make_jaxpr(S.make_engine_decode(
            cfg, msb_skip=True, with_telemetry=False))(*args))
    out = []
    jaxprcheck.check_msb_skip(full, draft, out)
    jaxprcheck.check_acc_dtype(full, out)
    jaxprcheck.check_acc_dtype(draft, out)
    jaxprcheck.check_callbacks(full, out)
    jaxprcheck.check_callbacks(draft, out)
    assert out == [], "\n".join(f.render() for f in out)
    # and the empirical anchor: the dual-pass full step really carries
    # shift-fed MSB dots for the detector to see
    total, shift_fed = jaxprcheck.count_int_plane_dots(full.jaxpr.jaxpr)
    assert total == 2 * shift_fed > 0


@pytest.mark.slow
def test_repo_jaxpr_layer_green_single_device():
    fs = jaxprcheck.run(with_mesh=False)
    active, _ = apply_allowlist(fs, Allowlist.load())
    assert active == [], "\n".join(f.render() for f in active)


@pytest.mark.slow
def test_cli_check_green_with_mesh():
    # the exact CI gate: both layers, mesh traces on 4 forced host
    # devices, committed allowlist, exit 0
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--check"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 finding(s)" in r.stdout
    assert "stale allowlist entry" not in r.stdout


def test_allowlist_file_exists_with_reasons():
    al = Allowlist.load(ALLOWLIST_PATH)
    assert al.entries, "committed allowlist must not be empty"
    for e in al.entries:
        assert len(e.reason) > 10, f"entry {e.pattern} needs a reason"
