"""Substrate subsystems: data pipeline, optimizer, checkpoint, fault loop,
sharding rules, MoE and SSD numerics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.fault import (FaultInjector, RestartableLoop,
                                     StepFault)
from repro.distributed.sharding import spec_for
from repro.models import moe as moe_lib
from repro.models import ssd as ssd_lib
from repro.optim.adamw import (OptConfig, adamw_update, compress_grads,
                               cosine_lr, decompress_grads, global_norm,
                               init_opt_state)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    d = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4))
    b1 = d.batch_at(7)
    b2 = d.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host_slice materializes exactly its rows
    half = d.batch_at(7, host_slice=slice(2, 4))
    np.testing.assert_array_equal(half["tokens"], b1["tokens"][2:4])


def test_data_targets_are_shifted_tokens():
    d = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=2))
    b = d.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_data_is_learnable_structure():
    """The Markov grammar bounds the successor set: each token has <= 8
    successors (so a model CAN learn it)."""
    d = SyntheticLM(DataConfig(vocab=64, seq_len=256, global_batch=8))
    b = d.batch_at(0)
    succ = {}
    for row_t, row_g in zip(b["tokens"], b["targets"]):
        for a, bb in zip(row_t, row_g):
            succ.setdefault(int(a), set()).add(int(bb))
    non_eos = {k: v for k, v in succ.items() if k != 0}
    avg = np.mean([len(v) for v in non_eos.values()])
    assert avg <= 9  # 8 successors + eos


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    ocfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=100,
                     weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params, ocfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(params, grads, state, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_cosine_schedule_shape():
    ocfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                     min_lr_ratio=0.1)
    lrs = [float(cosine_lr(ocfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


def test_grad_clip_and_norm():
    g = {"a": jnp.ones((3,)) * 4.0}
    assert float(global_norm(g)) == pytest.approx(np.sqrt(48))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_grad_compression_error_feedback(seed):
    """EF compression: quantization residual is carried, so the SUM of
    decompressed grads over steps tracks the true sum (bias-free)."""
    key = jax.random.PRNGKey(seed)
    true_sum = jnp.zeros((32,))
    sent_sum = jnp.zeros((32,))
    err = None
    for i in range(8):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (32,))}
        q, err = compress_grads(g, err)
        sent = decompress_grads(q)
        true_sum = true_sum + g["w"]
        sent_sum = sent_sum + sent["w"]
    resid = np.abs(np.asarray(sent_sum - true_sum)).max()
    # leftover error is bounded by one quantization step
    assert resid <= float(err["w"].__abs__().max()) + 1e-5


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), t, 5)
    assert store.latest_step(str(tmp_path)) == 5
    r = store.restore(str(tmp_path), 5, t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(r)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        assert a.dtype == b.dtype


def test_checkpoint_incomplete_ignored(tmp_path):
    t = _tree()
    store.save(str(tmp_path), t, 5)
    # corrupt a later checkpoint: manifest says writing
    os.makedirs(tmp_path / "step_000000009")
    with open(tmp_path / "step_000000009" / "manifest.json", "w") as f:
        f.write('{"status": "writing"}')
    assert store.latest_step(str(tmp_path)) == 5


def test_checkpoint_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        store.save(str(tmp_path), t, s)
    store.prune(str(tmp_path), keep=2)
    assert store.latest_step(str(tmp_path)) == 4
    assert store.restore(str(tmp_path), 3, t) is not None
    with pytest.raises(FileNotFoundError):
        store.restore(str(tmp_path), 1, t)


def test_async_writer(tmp_path):
    w = store.AsyncWriter(str(tmp_path))
    t = _tree()
    for s in (10, 20):
        w.submit(t, s)
    w.close()
    assert store.latest_step(str(tmp_path)) == 20


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restartable_loop_recovers(tmp_path):
    calls = []

    def step_fn(state, batch):
        calls.append(int(batch))
        return {"x": state["x"] + batch}, {"loss": state["x"]}

    inj = FaultInjector(plan={7: "fail"})
    loop = RestartableLoop(step_fn, lambda s: jnp.asarray(s),
                           str(tmp_path), ckpt_every=5, injector=inj)
    state, _ = loop.run({"x": jnp.asarray(0)}, 0, 10)
    # sum over steps 0..9 regardless of the injected failure/replay
    assert int(state["x"]) == sum(range(10))
    assert loop.report.restarts == 1 and loop.report.faults_seen == 1


def test_restartable_loop_budget_exhausted(tmp_path):
    def bad_step(state, batch):
        raise StepFault("always")

    loop = RestartableLoop(bad_step, lambda s: jnp.asarray(s),
                           str(tmp_path), ckpt_every=5, max_restarts=2)
    with pytest.raises(RuntimeError):
        loop.run({"x": jnp.asarray(0)}, 0, 4)


def test_elastic_restore_across_mesh(tmp_path):
    """A checkpoint written under one mesh restores under another
    (resharding happens at device_put; here 1-device degenerate case
    exercises the API path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    store.save(str(tmp_path), t, 1)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    r = store.restore(str(tmp_path), 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_spec_for_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # model axis size 1 -> everything degrades to unsharded
    p = spec_for(("batch", "seq", "mlp"), (8, 16, 32), mesh)
    assert all(e is None for e in p)


def test_spec_for_used_axis_filtering():
    # fake a 2x2 mesh over (data, model) using 1 device? -> need real mesh
    # sizes; emulate with a 1x1 and rule logic via direct call is limited.
    # Validate the priority logic shape-only with a (data=1, model=1) mesh:
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p = spec_for(("batch", "kv_seq", "kv_heads", None), (4, 64, 2, 16),
                 mesh)
    assert len(p) == 0 or all(e is None for e in p)


# ---------------------------------------------------------------------------
# MoE / SSD numerics
# ---------------------------------------------------------------------------

def test_moe_dispatch_matches_dense_computation():
    """With ample capacity, sort-based dispatch == explicit per-token
    expert evaluation."""
    key = jax.random.PRNGKey(0)
    t, d, e, f, k = 16, 8, 4, 12, 2
    x = jax.random.normal(key, (t, d))
    wr = jax.random.normal(jax.random.PRNGKey(1), (d, e))
    wg = jax.random.normal(jax.random.PRNGKey(2), (e, d, f)) * 0.3
    wu = jax.random.normal(jax.random.PRNGKey(3), (e, d, f)) * 0.3
    wd = jax.random.normal(jax.random.PRNGKey(4), (e, f, d)) * 0.3
    y = moe_lib.moe_ffn(x, wr, wg, wu, wd, top_k=k, capacity_factor=4.0)

    topv, topi = moe_lib.router(x, wr, "softmax", k)
    ref = jnp.zeros((t, d))
    for ti in range(t):
        for kk in range(k):
            ei = int(topi[ti, kk])
            h = jax.nn.silu(x[ti] @ wg[ei]) * (x[ti] @ wu[ei])
            ref = ref.at[ti].add(float(topv[ti, kk]) * (h @ wd[ei]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_overflow():
    x = jnp.ones((8, 4))
    wr = jnp.zeros((4, 2)).at[:, 0].set(1.0)  # all tokens -> expert 0
    wg = jnp.ones((2, 4, 4)); wu = jnp.ones((2, 4, 4))
    wd = jnp.ones((2, 4, 4))
    y = moe_lib.moe_ffn(x, wr, wg, wu, wd, top_k=1, capacity_factor=0.5)
    # capacity = 8*1*0.5/2 = 2 slots; 6 of 8 tokens dropped -> zero rows
    zero_rows = (np.abs(np.asarray(y)).sum(-1) < 1e-6).sum()
    assert zero_rows == 6


def test_ssd_chunked_equals_stepwise():
    """Chunked SSD scan == token-by-token recurrence (state-space duality
    correctness)."""
    b, l, g, hg, p, n = 2, 12, 1, 3, 4, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, l, g, hg, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (b, l, g, hg)))
    a_log = jax.random.normal(jax.random.PRNGKey(2), (g, hg)) * 0.1
    bb = jax.random.normal(jax.random.PRNGKey(3), (b, l, g, n))
    cc = jax.random.normal(jax.random.PRNGKey(4), (b, l, g, n))
    dsk = jnp.ones((g, hg)) * 0.5
    y_chunk, h_chunk = ssd_lib.ssd_chunked(x, dt, a_log, bb, cc, dsk,
                                           chunk=4)
    h = jnp.zeros((b, g, hg, p, n))
    ys = []
    for t in range(l):
        y_t, h = ssd_lib.ssd_decode_step(h, x[:, t], dt[:, t], a_log,
                                         bb[:, t], cc[:, t], dsk)
        ys.append(y_t)
    y_step = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_pad_invariance():
    """Non-divisible seq len (internal padding) gives the same prefix."""
    b, l, g, hg, p, n = 1, 10, 1, 2, 4, 4
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, l, g, hg, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (b, l, g, hg)))
    a_log = jnp.zeros((g, hg))
    bb = jax.random.normal(jax.random.PRNGKey(3), (b, l, g, n))
    cc = jax.random.normal(jax.random.PRNGKey(4), (b, l, g, n))
    dsk = jnp.zeros((g, hg))
    y4, _ = ssd_lib.ssd_chunked(x, dt, a_log, bb, cc, dsk, chunk=4)
    y10, _ = ssd_lib.ssd_chunked(x, dt, a_log, bb, cc, dsk, chunk=10)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y10),
                               rtol=1e-4, atol=1e-4)
