"""End-to-end system tests: train -> checkpoint -> restore -> quantize ->
SPARQLe serve, with fault injection — the whole production path on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.core.qlinear import quantize_model_params
from repro.core.quantize import quantize_activations
from repro.core.sparqle import subprecision_sparsity
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.fault import FaultInjector, RestartableLoop
from repro.launch import steps as S
from repro.models import model as M
from repro.models.registry import SMOKES
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.optim.adamw import OptConfig, init_opt_state

pytestmark = pytest.mark.slow  # end-to-end train/serve: minutes of jit time


@pytest.fixture(scope="module")
def trained():
    """Train the granite smoke model briefly on synthetic data."""
    cfg = SMOKES["granite-8b"].replace(vocab=256)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8, seed=3))
    ocfg = OptConfig(lr=3e-3, warmup_steps=10, total_steps=200)
    step = jax.jit(S.make_train_step(
        cfg, ocfg, S.TrainKnobs(microbatch=4, ce_chunk=32)),
        donate_argnums=0)
    params = init_params(build_schema(cfg), jax.random.PRNGKey(0))
    state = S.TrainState(params, init_opt_state(params, ocfg))
    losses = []
    for i in range(200):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return cfg, data, state, losses


def test_training_learns(trained):
    cfg, data, state, losses = trained
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_train_with_fault_recovery_matches_clean_run(tmp_path, trained):
    """A run with an injected failure converges to the SAME state as a
    clean run (deterministic data + checkpoint replay)."""
    cfg, data, _, _ = trained
    ocfg = OptConfig(lr=2e-3, warmup_steps=2, total_steps=20)
    step = jax.jit(S.make_train_step(cfg, ocfg, S.TrainKnobs(ce_chunk=32)))

    def make_batch(i):
        return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    def run(ckdir, injector):
        params = init_params(build_schema(cfg), jax.random.PRNGKey(1))
        st = S.TrainState(params, init_opt_state(params, ocfg))
        loop = RestartableLoop(step, make_batch, str(ckdir),
                               ckpt_every=5, injector=injector)
        st, _ = loop.run(st, 0, 12)
        return st, loop

    st_clean, _ = run(tmp_path / "clean", None)
    st_fault, loop = run(tmp_path / "fault",
                         FaultInjector(plan={8: "fail"}))
    assert loop.report.restarts == 1
    for a, b in zip(jax.tree_util.tree_leaves(st_clean.params),
                    jax.tree_util.tree_leaves(st_fault.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_full_state(tmp_path, trained):
    cfg, _, state, _ = trained
    store.save(str(tmp_path), state, 42)
    restored = store.restore(str(tmp_path), 42, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantized_serving_of_trained_model(trained):
    """The paper's deployment: quantize the trained model W4A8 + clipping
    and decode greedily — outputs stay close to the float model, and the
    trained activations show real sub-precision sparsity."""
    cfg, data, state, _ = trained
    params = state.params
    qparams = quantize_model_params(params, w_bits=4, k_percent=50.0,
                                    tile_k=16)
    B, P, GEN = 2, 32, 6
    prompts = jnp.asarray(data.batch_at(500)["tokens"])[:B, :P]

    def decode_n(p):
        tok, cache = S.make_serve_prefill(cfg, P + GEN)(
            p, {"tokens": prompts})
        outs = [tok]
        for i in range(GEN - 1):
            tok, cache = S.make_serve_decode(cfg)(
                p, cache, tok, jnp.full((B,), P + i, jnp.int32))
            outs.append(tok)
        return jnp.stack(outs, 1)

    gen_f = decode_n(params)
    gen_q = decode_n(qparams)
    agree = float((gen_f == gen_q).mean())
    assert agree >= 0.5, f"greedy agreement {agree} too low"

    hidden = M.forward_hidden(cfg, params, {"tokens": prompts})
    q8 = quantize_activations(hidden.reshape(-1, hidden.shape[-1]),
                              bits=8, per_token=True).q
    s = float(subprecision_sparsity(q8))
    # sanity floor only — the quantitative sparsity claims are measured on
    # the properly-sized benchmark model (benchmarks/bench_compression.py:
    # 28-45% at linear inputs); this 64-dim smoke model quantizes coarsely
    assert s > 0.08, f"trained activations should be MSB4-sparse, got {s}"


def test_compressed_grad_training_converges(trained):
    """int8 EF gradient compression doesn't break optimization."""
    cfg, data, _, _ = trained
    ocfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(S.make_train_step(
        cfg, ocfg, S.TrainKnobs(ce_chunk=32, compress_pod_grads=True)))
    params = init_params(build_schema(cfg), jax.random.PRNGKey(2))
    st = S.TrainState(params, init_opt_state(params, ocfg))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2
