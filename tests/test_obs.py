"""Observability stack: metrics registry, tracer, engine instrumentation.

Fast tests cover the pure ``repro.obs`` machinery (registry semantics,
histogram math, Chrome-trace export, artifact validators) plus the
fault-loop registry wiring and the NaN-guard edges of ``Request.stats``.
The ``@pytest.mark.slow`` tests drive a real (tiny) engine with an
injected deterministic clock and pin the schema contracts downstream
tooling depends on: ``aggregate_stats()`` / ``Request.stats()`` /
registry-snapshot key sets, latency-histogram consistency with the
mean-based per-request stats, and Perfetto validity of the exported
trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.obs import (DEFAULT_LATENCY_BUCKETS, MetricsRegistry,
                       Observability, Tracer)
from repro.obs.validate import validate_chrome_trace, validate_snapshot
from repro.serving import (Engine, SamplingParams, SpecConfig,
                           SpeculativeEngine)
from repro.serving.scheduler import Request

CFG = ModelConfig(name="tiny-serve", family="transformer", n_layers=2,
                  d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                  d_ff=64, vocab=128, dtype="float32")


@pytest.fixture(scope="module")
def qparams():
    fparams = init_params(build_schema(CFG), jax.random.PRNGKey(0))
    return quantize_model_params(
        fparams, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)


class FakeClock:
    """Deterministic monotonic clock: every read advances by ``dt``."""

    def __init__(self, dt=0.001):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_counter_and_gauge_basics():
    r = MetricsRegistry()
    c = r.counter("reqs_total", "requests", unit="requests")
    c.inc()
    c.inc(2.5)
    assert r.value("reqs_total") == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("queue_depth", "waiting", unit="requests")
    g.set(4)
    g.inc(2)
    assert r.value("queue_depth") == 6.0


def test_labels_declared_and_enforced():
    r = MetricsRegistry()
    c = r.counter("tokens_total", "t", unit="tokens",
                  labelnames=("phase",))
    c.inc(3, phase="prefill")
    c.inc(1, phase="decode")
    assert c.value(phase="prefill") == 3.0
    with pytest.raises(ValueError):
        c.inc(1)                          # missing label
    with pytest.raises(ValueError):
        c.inc(1, phase="x", shard="0")    # undeclared label


def test_metric_name_and_unit_validation():
    r = MetricsRegistry()
    for bad in ("Bad", "0start", "has-dash", "has space", ""):
        with pytest.raises(ValueError):
            r.counter(bad, unit="1")
    with pytest.raises(ValueError):
        r.counter("no_unit", unit="")
    with pytest.raises(ValueError):
        r.counter("bad_label", unit="1", labelnames=("Nope",))


def test_reregister_create_or_get():
    r = MetricsRegistry()
    a = r.counter("dup_total", "x", unit="tokens")
    assert r.counter("dup_total", "x", unit="tokens") is a
    with pytest.raises(ValueError):
        r.gauge("dup_total", unit="tokens")           # kind mismatch
    with pytest.raises(ValueError):
        r.counter("dup_total", unit="bytes")          # unit mismatch
    with pytest.raises(ValueError):
        r.counter("dup_total", unit="tokens", labelnames=("a",))


# ---------------------------------------------------------------------------
# histogram math
# ---------------------------------------------------------------------------

def test_histogram_observe_and_moments():
    r = MetricsRegistry()
    h = r.histogram("lat_seconds", "latency", unit="seconds",
                    buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.count() == 5
    assert h.sum() == pytest.approx(106.5)
    assert h.mean() == pytest.approx(106.5 / 5)
    with pytest.raises(ValueError):
        h.observe(float("nan"))


def test_histogram_percentile_interpolation():
    r = MetricsRegistry()
    h = r.histogram("lat_seconds", unit="seconds", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0):
        h.observe(v)
    # rank 2 of 4 -> second observation, inside (1, 2]: interpolated
    p50 = h.percentile(50)
    assert 1.0 <= p50 <= 2.0
    # overflow observations clamp to the last finite bound
    h.observe(999.0)
    assert h.percentile(100) == 4.0
    # empty series -> nan, bad q -> raises
    assert np.isnan(r.histogram("empty_seconds", unit="seconds")
                    .percentile(50))
    with pytest.raises(ValueError):
        h.percentile(101)


def test_histogram_time_uses_injected_clock():
    clk = FakeClock(dt=0.5)
    r = MetricsRegistry(clock=clk)
    h = r.histogram("step_seconds", unit="seconds", labelnames=("phase",))
    with h.time(phase="decode"):
        pass
    # one clock tick inside the block -> exactly dt observed
    assert h.sum(phase="decode") == pytest.approx(0.5)
    assert h.count(phase="decode") == 1


def test_histogram_rejects_bad_buckets():
    r = MetricsRegistry()
    bads = ((), (2.0, 1.0), (1.0, 1.0), (1.0, float("inf")))
    for i, bad in enumerate(bads):
        with pytest.raises(ValueError):
            r.histogram(f"h{i}_seconds", unit="seconds", buckets=bad)


# ---------------------------------------------------------------------------
# snapshot / exposition / validators
# ---------------------------------------------------------------------------

def test_snapshot_schema_and_validator():
    r = MetricsRegistry()
    r.counter("a_total", "help a", unit="tokens").inc(3)
    r.gauge("b_ratio", unit="ratio", labelnames=("shard",)).set(0.5,
                                                                shard="0")
    h = r.histogram("c_seconds", unit="seconds", buckets=(1.0, 2.0))
    h.observe(1.5)
    snap = r.snapshot()
    assert validate_snapshot(snap) == []
    assert set(snap) == {"a_total", "b_ratio", "c_seconds"}
    entry = snap["c_seconds"]
    assert entry["type"] == "histogram" and entry["unit"] == "seconds"
    s = entry["series"][0]
    assert set(s) == {"labels", "count", "sum", "bucket_counts",
                      "p50", "p90", "p99"}
    assert len(s["bucket_counts"]) == len(entry["buckets"]) + 1
    # corrupt it -> validator flags
    s["bucket_counts"].append(7)
    assert validate_snapshot(snap)


def test_render_text_exposition():
    r = MetricsRegistry()
    r.counter("reqs_total", "requests seen", unit="requests").inc(2)
    h = r.histogram("lat_seconds", unit="seconds", buckets=(1.0, 2.0),
                    labelnames=("phase",))
    h.observe(0.5, phase="p")
    h.observe(1.5, phase="p")
    text = r.render_text()
    assert "# TYPE reqs_total counter" in text
    assert "# UNIT reqs_total requests" in text
    assert "reqs_total 2" in text
    # cumulative le buckets + +Inf + _sum/_count
    assert 'lat_seconds_bucket{phase="p",le="1"} 1' in text
    assert 'lat_seconds_bucket{phase="p",le="2"} 2' in text
    assert 'lat_seconds_bucket{phase="p",le="+Inf"} 2' in text
    assert 'lat_seconds_count{phase="p"} 2' in text


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_spans_instants_and_export():
    clk = FakeClock(dt=1.0)
    tr = Tracer(clock=clk)
    tr.set_track_name(0, "engine")
    with tr.span("engine_step", step=0):
        tr.instant("finished", rid=3)
    trace = tr.export()
    assert validate_chrome_trace(trace) == []
    evs = trace["traceEvents"]
    names = [e["name"] for e in evs]
    assert "process_name" in names and "thread_name" in names
    x = next(e for e in evs if e["ph"] == "X")
    assert x["name"] == "engine_step" and x["dur"] > 0
    assert x["args"] == {"step": 0}
    i = next(e for e in evs if e["ph"] == "i")
    assert i["args"] == {"rid": 3}


def test_tracer_open_spans_flushed_and_ring_bound():
    tr = Tracer(clock=FakeClock(), capacity=4)
    h = tr.begin("lifecycle", track=5, phase="waiting")
    trace = tr.export()          # still open -> flushed read-only
    assert any(e["name"] == "lifecycle" and e["tid"] == 5
               for e in trace["traceEvents"])
    tr.end(h)
    tr.end(h)                    # double-end is a no-op
    for i in range(10):
        tr.instant("tick")
    assert len(tr) == 4 and tr.dropped > 0
    assert validate_chrome_trace(tr.export()) == []


def test_tracer_disabled_is_noop():
    tr = Tracer(enabled=False)
    assert tr.begin("x") is None
    with tr.span("y"):
        tr.instant("z")
    assert len(tr) == 0


# ---------------------------------------------------------------------------
# Request.stats NaN guards (no engine needed)
# ---------------------------------------------------------------------------

def test_request_stats_nan_before_any_token():
    req = Request(rid=0, prompt=[1, 2, 3], sampling=SamplingParams(),
                  arrival=0.0)
    s = req.stats()
    for key in ("ttft_s", "tpot_s", "act_sparsity",
                "act_wire_bytes_per_token", "act_wire_compression_pct",
                "spec_acceptance_rate", "spec_tokens_per_step"):
        assert np.isnan(s[key]), key
    assert s["n_generated"] == 0
    assert s["wire_tokens"] == 0 and s["draft_tokens"] == 0


def test_preempted_before_first_token_observes_no_nan():
    """A request preempted (then never resumed) before emitting must not
    feed NaN into the latency histograms — _emit guards on t_first."""
    obs = Observability(clock=FakeClock())
    req = Request(rid=1, prompt=[1], sampling=SamplingParams(),
                  arrival=0.0, preemptions=1)
    s = req.stats()
    assert np.isnan(s["ttft_s"]) and s["preemptions"] == 1
    # registry histograms stay empty (observe(nan) would have raised)
    assert obs.registry.histogram(
        "serving_ttft_seconds", unit="seconds").count() == 0
    assert validate_snapshot(obs.registry.snapshot()) == []


# ---------------------------------------------------------------------------
# fault-loop registry wiring
# ---------------------------------------------------------------------------

def test_restartable_loop_registry_counters(tmp_path):
    from repro.distributed.fault import FaultInjector, RestartableLoop

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {"loss": state["x"]}

    reg = MetricsRegistry()
    inj = FaultInjector(plan={7: "fail"})
    loop = RestartableLoop(step_fn, lambda s: jnp.asarray(s),
                           str(tmp_path), ckpt_every=5, injector=inj,
                           registry=reg)
    state, _ = loop.run({"x": jnp.asarray(0)}, 0, 10)
    assert int(state["x"]) == sum(range(10))
    # registry mirrors the LoopReport exactly
    assert reg.value("fault_steps_run_total") == loop.report.steps_run
    assert reg.value("fault_faults_total") == loop.report.faults_seen == 1
    assert reg.value("fault_restarts_total") == loop.report.restarts == 1
    assert reg.value("fault_restores_total") == loop.report.restores == 1
    # initial + step-5 + step-10(final) checkpoints at minimum
    assert reg.value("fault_checkpoints_total") >= 3
    assert reg.value("fault_time_lost_seconds") >= 0.0
    assert validate_snapshot(reg.snapshot()) == []


def test_restartable_loop_without_registry_unchanged(tmp_path):
    from repro.distributed.fault import RestartableLoop

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {}

    loop = RestartableLoop(step_fn, lambda s: jnp.asarray(s),
                           str(tmp_path), ckpt_every=5)
    state, _ = loop.run({"x": jnp.asarray(0)}, 0, 6)
    assert int(state["x"]) == sum(range(6))


# ---------------------------------------------------------------------------
# engine integration (slow: real jitted steps)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_metrics_and_trace_end_to_end(qparams):
    clk = FakeClock(dt=0.001)
    eng = Engine(CFG, qparams, clock=clk,
                 obs=Observability(clock=clk))
    rng = np.random.default_rng(0)
    handles = [eng.submit(list(rng.integers(1, 127, size=12)),
                          SamplingParams(max_new_tokens=6,
                                         temperature=0.0))
               for _ in range(3)]
    eng.run()

    # -- aggregate_stats key set pinned (downstream consumers) --
    agg = eng.aggregate_stats()
    assert set(agg) == {"steps", "pool_pages_free", "pool_utilization",
                        "pool_evictions", "wire_bytes_total",
                        "wire_compression_pct",
                        "layer_wire_bytes_per_token",
                        "layer_dense_bytes_per_token"}
    assert agg["steps"] == eng.steps
    assert len(agg["layer_wire_bytes_per_token"]) == CFG.n_layers

    # -- Request.stats key set pinned --
    s = handles[0].stats()
    assert set(s) == {"ttft_s", "tpot_s", "n_generated", "act_sparsity",
                      "act_wire_bytes_per_token", "wire_tokens",
                      "draft_tokens", "act_wire_compression_pct",
                      "preemptions", "spec_acceptance_rate",
                      "spec_tokens_per_step", "kv_demotions",
                      "kv_promotions"}
    assert s["kv_demotions"] == 0 and s["kv_promotions"] == 0  # disarmed

    # -- registry totals consistent with per-request truths --
    r = eng.obs.registry
    n_tok = sum(h.stats()["n_generated"] for h in handles)
    assert r.value("serving_tokens_emitted_total") == n_tok
    assert r.value("serving_requests_finished_total") == len(handles)
    wire_sum = sum(h.stats()["act_wire_bytes_per_token"]
                   * h.stats()["wire_tokens"] for h in handles)
    assert r.value("serving_wire_bytes_total") == pytest.approx(wire_sum)

    # -- latency histograms vs exact per-request stats --
    ttfts = sorted(h.stats()["ttft_s"] for h in handles)
    hist = r.get("serving_ttft_seconds")
    assert hist.count() == len(handles)
    assert hist.sum() == pytest.approx(sum(ttfts))   # sums are exact
    # bucket-interpolated p50 must land in the bucket holding the true
    # median (histogram resolution is the bucket width, nothing finer)
    median = ttfts[len(ttfts) // 2]
    bounds = [0.0] + list(DEFAULT_LATENCY_BUCKETS)
    idx = next(i for i in range(len(bounds) - 1)
               if bounds[i] < median <= bounds[i + 1])
    p50 = hist.percentile(50)
    assert bounds[idx] <= p50 <= bounds[idx + 1]
    tpot_hist = r.get("serving_tpot_seconds")
    assert tpot_hist.count() == n_tok - len(handles)  # gaps, not tokens

    # -- snapshot + trace artifacts validate --
    snap = eng.metrics_snapshot()
    assert validate_snapshot(snap) == []
    # per-layer gauges populated for every layer
    layers = {s_["labels"]["layer"]
              for s_ in snap["serving_layer_wire_bytes_per_token"]["series"]}
    assert layers == {str(i) for i in range(CFG.n_layers)}
    trace = eng.obs.tracer.export()
    assert validate_chrome_trace(trace) == []
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"engine_step", "prefill_chunk", "decode_batch",
            "waiting", "prefill", "decode"} <= names
    # per-request lifecycle tracks are named
    assert any(e["ph"] == "M" and e["name"] == "thread_name"
               and "request" in e["args"]["name"]
               for e in trace["traceEvents"])


@pytest.mark.slow
def test_spec_engine_draft_token_accounting(qparams):
    eng = SpeculativeEngine(CFG, qparams, spec=SpecConfig(gamma=2))
    rng = np.random.default_rng(0)
    handles = [eng.submit(list(rng.integers(1, 127, size=12)),
                          SamplingParams(max_new_tokens=8,
                                         temperature=0.0))
               for _ in range(2)]
    eng.run()
    r = eng.obs.registry
    for h in handles:
        s = h.stats()
        # drafts excluded from the wire denominator: telemetered tokens
        # only (prefill chunks + γ+1 verify windows), drafts separate
        assert s["wire_tokens"] == h.sparsity_n
        assert s["draft_tokens"] == eng.spec.gamma * h.spec_steps
        assert np.isfinite(s["act_wire_bytes_per_token"])
    agg = eng.aggregate_stats()
    assert agg["spec_gamma"] == 2
    assert (r.value("serving_spec_draft_proposed_total")
            == eng.draft_proposed_total)
    assert (r.value("serving_spec_draft_accepted_total")
            == eng.draft_accepted_total)
    assert agg["spec_acceptance_rate"] == pytest.approx(
        eng.draft_accepted_total / eng.draft_proposed_total)
    # draft/verify sub-phases timed inside each decode-batch phase
    step_lat = r.get("serving_step_seconds")
    n_batches = step_lat.count(phase="decode")
    assert n_batches > 0
    assert step_lat.count(phase="draft") == n_batches
    assert step_lat.count(phase="verify") == n_batches
    assert validate_snapshot(eng.metrics_snapshot()) == []
