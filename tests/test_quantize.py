"""Quantization substrate: W4/W2/A8/KV4 + the qlinear dispatch layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.core.qlinear import (SparqleLinear, expert_linear, linear,
                                quantize_leaf, quantize_model_params)
from repro.core.quantize import (fake_quantize, quantize_activations,
                                 quantize_kv, quantize_weights)
from repro.core.sparqle import subprecision_sparsity


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_weight_quant_range_and_error(bits):
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.3
    qt = quantize_weights(w, bits=bits, axis=0)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = np.asarray(qt.q)
    assert q.min() >= lo and q.max() <= hi
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(w))
    # error bounded by half a quantization step per channel
    step = np.asarray(qt.scale)
    assert (err <= 0.5 * step + 1e-6).all()


def test_activation_quant_per_token_scales():
    x = jnp.stack([jnp.ones(16) * 0.1, jnp.ones(16) * 100.0])
    qt = quantize_activations(x, bits=8, per_token=True)
    assert qt.scale.shape == (2, 1)
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(x),
                               rtol=0.02)


def test_zero_point_adjustment_boosts_sparsity():
    """Paper §3.1: zero-point shift moves non-centered (SiLU-like)
    activations into the MSB4==0 range."""
    x = jax.nn.silu(jax.random.normal(jax.random.PRNGKey(1), (128, 128)) * 2)
    q_sym = quantize_activations(x, zero_point=False).q
    q_zp = quantize_activations(x, zero_point=True).q
    assert float(subprecision_sparsity(q_zp)) > \
        float(subprecision_sparsity(q_sym))


def test_kv4_roundtrip_error():
    kv = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 8, 32))
    qt = quantize_kv(kv, bits=4)
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(kv))
    rel = err.max() / np.abs(np.asarray(kv)).max()
    assert rel < 0.2


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_property_quant_monotone(seed, bits):
    """Quantization preserves per-channel ordering up to one step."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (32,)) * 2
    qt = quantize_weights(w.reshape(-1, 1), bits=bits, axis=0)
    deq = np.asarray(qt.dequantize()).ravel()
    worig = np.asarray(w)
    order = np.argsort(worig)
    assert (np.diff(deq[order]) >= -float(qt.scale.max()) - 1e-6).all()


def test_fake_quantize_shape_dtype():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 16))
    y = fake_quantize(x, bits=8)
    assert y.shape == x.shape


# ---------------------------------------------------------------------------
# qlinear dispatch
# ---------------------------------------------------------------------------

def test_linear_float_and_quantized_agree():
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 32)) * 0.2
    sl = quantize_leaf(w, w_bits=4, enable_clipping=False)
    yf = linear(x, w)
    yq = linear(x, sl)
    cos = float((yf * yq).sum() /
                (jnp.linalg.norm(yf) * jnp.linalg.norm(yq)))
    assert cos > 0.98


def test_sparqle_mode_equals_dense_mode():
    """Decomposition is exact: sparqle and dense served modes agree."""
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(7), (64, 32)) * 0.2
    sls = quantize_leaf(w, mode="sparqle", enable_clipping=False)
    sld = quantize_leaf(w, mode="dense", enable_clipping=False)
    np.testing.assert_allclose(np.asarray(linear(x, sls)),
                               np.asarray(linear(x, sld)), rtol=1e-5)


def test_expert_linear_quantized():
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 6, 32))   # (E, C, K)
    w = jax.random.normal(jax.random.PRNGKey(9), (4, 32, 16)) * 0.2
    sl = quantize_leaf(w, w_bits=4, enable_clipping=False)
    yf = expert_linear(x, w)
    yq = expert_linear(x, sl)
    cos = float((yf * yq).sum() /
                (jnp.linalg.norm(yf) * jnp.linalg.norm(yq)))
    assert cos > 0.98


def test_quantize_model_params_structure():
    params = {
        "stages": {"s0": {"p0": {
            "wq": jnp.ones((2, 16, 32)),            # stacked (L,K,N)
            "ln": {"gamma": jnp.zeros((2, 16))},
            "moe": {"w_gate": jnp.ones((4, 16, 8)),  # experts (E,K,N)
                    "w_router": jnp.ones((16, 4))},
        }}},
        "lm_head": jnp.ones((16, 64)),
    }
    q = quantize_model_params(params, tile_k=8)
    assert isinstance(q["stages"]["s0"]["p0"]["wq"], SparqleLinear)
    # int4 payload nibble-packed two-per-byte along K
    assert q["stages"]["s0"]["p0"]["wq"].w.q.shape == (2, 8, 32)
    assert q["stages"]["s0"]["p0"]["wq"].shape == (2, 16, 32)
    assert q["stages"]["s0"]["p0"]["wq"].w.scale.shape == (2, 1, 32)
    assert isinstance(q["stages"]["s0"]["p0"]["moe"]["w_gate"],
                      SparqleLinear)
    # router and norms untouched
    assert isinstance(q["stages"]["s0"]["p0"]["moe"]["w_router"], jax.Array)
    assert isinstance(q["stages"]["s0"]["p0"]["ln"]["gamma"], jax.Array)
    assert isinstance(q["lm_head"], SparqleLinear)
