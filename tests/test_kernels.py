"""Per-kernel allclose sweeps vs the pure-jnp oracles (kernels/ref.py).

Every Pallas kernel is validated in interpret mode on CPU across shapes,
sparsity levels and value ranges, plus hypothesis property tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.core import packing as packing_lib
from repro.core.quantize import quantize_weights
from repro.core.sparqle import encode, tile_population
from repro.kernels.ops import sparqle_linear
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.ref import (quant_matmul_ref, sparqle_encode_ref,
                               sparqle_matmul_ref)
from repro.kernels.sparqle_encode import sparqle_encode, sparqle_encode_packed
from repro.kernels.sparqle_matmul import sparqle_matmul, sparqle_matmul_packed


def _mk_inputs(key, m, k, n, sparsity=0.5):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # control sub-precision sparsity: values in [0,15] with prob `sparsity`
    small = jax.random.randint(k1, (m, k), 0, 16, dtype=jnp.int8)
    big = jax.random.randint(k2, (m, k), -128, 128, dtype=jnp.int8)
    pick = jax.random.uniform(k3, (m, k)) < sparsity
    x = jnp.where(pick, small, big).astype(jnp.int8)
    w = jax.random.randint(k4, (k, n), -8, 8, dtype=jnp.int8)
    asc = jax.random.uniform(k1, (m, 1), minval=0.5, maxval=2.0)
    wsc = jax.random.uniform(k2, (1, n), minval=0.5, maxval=2.0)
    return x, w, asc, wsc


SHAPES = [(128, 128, 128), (256, 384, 128), (128, 256, 256)]
SPARSITIES = [0.0, 0.5, 1.0]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("s", SPARSITIES)
def test_sparqle_matmul_allclose(m, k, n, s):
    x, w, asc, wsc = _mk_inputs(jax.random.PRNGKey(42), m, k, n, s)
    a = encode(x)
    pop = tile_population(a.pbm, 128, 128)
    out = sparqle_matmul(a.lsb4, a.msb4, pop, w, asc, wsc)
    ref = sparqle_matmul_ref(a.lsb4, a.msb4, w, asc, wsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_quant_matmul_allclose(m, k, n):
    x, w, asc, wsc = _mk_inputs(jax.random.PRNGKey(7), m, k, n)
    out = quant_matmul(x, w, asc, wsc)
    ref = quant_matmul_ref(x, w, asc, wsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_sparqle_vs_dense_identity():
    """The dual-pass kernel on (lsb, msb) equals the dense kernel on x —
    the numerical-equivalence claim of paper §3.3."""
    x, w, asc, wsc = _mk_inputs(jax.random.PRNGKey(3), 128, 256, 128, 0.7)
    a = encode(x)
    pop = tile_population(a.pbm, 128, 128)
    out_sparqle = sparqle_matmul(a.lsb4, a.msb4, pop, w, asc, wsc)
    out_dense = quant_matmul(x, w, asc, wsc)
    np.testing.assert_allclose(np.asarray(out_sparqle),
                               np.asarray(out_dense), rtol=1e-6)


def test_sparse_pass_skipping_correct():
    """Fully sub-precision-sparse input: all MSB tiles empty, result exact
    (the @pl.when skip must not change the output)."""
    x = jax.random.randint(jax.random.PRNGKey(0), (128, 256), 0, 16,
                           dtype=jnp.int8)
    w = jax.random.randint(jax.random.PRNGKey(1), (256, 128), -8, 8,
                           dtype=jnp.int8)
    asc = jnp.ones((128, 1)); wsc = jnp.ones((1, 128))
    a = encode(x)
    pop = tile_population(a.pbm, 128, 128)
    assert int(pop.sum()) == 0
    out = sparqle_matmul(a.lsb4, a.msb4, pop, w, asc, wsc)
    ref = quant_matmul_ref(x, w, asc, wsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("m,k,n,s", [(128, 256, 128, 0.5),
                                     (256, 128, 128, 0.0),
                                     (128, 128, 256, 1.0)])
def test_sparqle_matmul_packed_bitexact_vs_unpacked(m, k, n, s):
    """The packed-plane kernel must reproduce the unpacked kernel bit for
    bit on all-int8 inputs — same tile body, in-VMEM unpack."""
    x, w, asc, wsc = _mk_inputs(jax.random.PRNGKey(13), m, k, n, s)
    a = encode(x)
    pop = tile_population(a.pbm, 128, 128)
    ref = sparqle_matmul(a.lsb4, a.msb4, pop, w, asc, wsc)
    out = sparqle_matmul_packed(
        packing_lib.pack_nibbles(a.lsb4), packing_lib.pack_nibbles(a.msb4),
        pop, w, asc, wsc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_sparqle_matmul_packed_exhaustive_nibbles():
    """All 256 int8 values through the packed path: exact vs the jnp
    oracle (the acceptance-criterion sweep)."""
    # every int8 value appears: the full ramp reshaped to a 128x128 tile
    x = jnp.arange(-128, 128, dtype=jnp.int8).reshape(2, 128).repeat(64, 0)
    w = jax.random.randint(jax.random.PRNGKey(1), (128, 128), -8, 8,
                           dtype=jnp.int8)
    asc = jnp.ones((128, 1)); wsc = jnp.ones((1, 128))
    a = encode(x)
    pop = tile_population(a.pbm, 128, 128)
    out = sparqle_matmul_packed(
        packing_lib.pack_nibbles(a.lsb4), packing_lib.pack_nibbles(a.msb4),
        pop, w, asc, wsc)
    ref = quant_matmul_ref(x, w, asc, wsc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_sparqle_linear_wire_formats_bitexact():
    """ops.sparqle_linear produces identical outputs for both activation
    wire formats (packed path shares the kernel body)."""
    x = jax.random.normal(jax.random.PRNGKey(21), (64, 192))
    w = quantize_weights(
        jax.random.normal(jax.random.PRNGKey(22), (192, 96)) * 0.1,
        bits=4, axis=0)
    a = sparqle_linear(x, w, wire_format="unpacked")
    b = sparqle_linear(x, w, wire_format="packed")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sparqle_encode_packed_kernel_matches_codec():
    """The packed drain kernel emits exactly the core/packing.py layout."""
    x = jax.random.normal(jax.random.PRNGKey(5), (256, 256)) * 30
    scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (256, 1))) + 0.5
    lp, mp, words, pop = sparqle_encode_packed(x, scale)
    l, m_, pbm, pop_ref = sparqle_encode(x, scale)
    np.testing.assert_array_equal(
        np.asarray(packing_lib.unpack_nibbles(lp, signed=False)),
        np.asarray(l))
    np.testing.assert_array_equal(
        np.asarray(packing_lib.unpack_nibbles(mp, signed=True)),
        np.asarray(m_))
    np.testing.assert_array_equal(
        np.asarray(packing_lib.unpack_pbm(words, 256)), np.asarray(pbm))
    np.testing.assert_array_equal(np.asarray(pop), np.asarray(pop_ref))


def test_sparqle_encode_zero_scale_rows():
    """Zero (or denormal) per-token scales must encode to exact zeros, not
    the ±127 garbage inf/nan rounding used to produce — the padded-prefill
    null-page case."""
    x = jnp.zeros((128, 128))
    for s0 in (0.0, 1e-40):           # zero and denormal divisors
        scale = jnp.full((128, 1), s0)
        lsb, msb, pbm, pop = sparqle_encode(x, scale)
        assert int(jnp.abs(lsb).sum()) == 0
        assert int(jnp.abs(msb).sum()) == 0
        assert not bool(pbm.any()) and int(pop.sum()) == 0
    # a zero-scale row among live rows is guarded row-wise
    xm = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 20
    xm = xm.at[3].set(0.0)
    scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (128, 1))) + 0.5
    scale = scale.at[3].set(0.0)
    lsb, msb, _, _ = sparqle_encode(xm, scale)
    assert int(jnp.abs(lsb[3]).sum()) == 0 and int(jnp.abs(msb[3]).sum()) == 0
    q = jnp.clip(jnp.round(xm[4] / scale[4]), -128, 127).astype(jnp.int8)
    lref, mref, _ = sparqle_encode_ref(q)
    np.testing.assert_array_equal(np.asarray(lsb[4]), np.asarray(lref))


@pytest.mark.parametrize("bm,bk", [(128, 128), (128, 256)])
def test_sparqle_encode_kernel(bm, bk):
    x = jax.random.normal(jax.random.PRNGKey(5), (256, 256)) * 30
    scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (256, 1))) + 0.5
    lsb, msb, pbm, pop = sparqle_encode(x, scale, bm=bm, bk=bk)
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    lref, mref, pref = sparqle_encode_ref(q)
    np.testing.assert_array_equal(np.asarray(lsb), np.asarray(lref))
    np.testing.assert_array_equal(np.asarray(msb), np.asarray(mref))
    np.testing.assert_array_equal(np.asarray(pbm), np.asarray(pref))
    np.testing.assert_array_equal(
        np.asarray(pop), np.asarray(tile_population(pref, bm, bk)))


@pytest.mark.parametrize("shape", [(5, 100), (3, 7, 64), (130, 200)])
@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_sparqle_linear_unaligned_shapes(shape, backend):
    """ops.sparqle_linear pads arbitrary shapes and matches a float matmul
    up to quantization error."""
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, shape)
    wf = jax.random.normal(jax.random.PRNGKey(12), (shape[-1], 96)) * 0.1
    w = quantize_weights(wf, bits=4, axis=0)
    out = sparqle_linear(x, w, backend=backend)
    ref = x @ w.dequantize()
    # int8 act + int4 weight quantization error bound (loose)
    err = np.abs(np.asarray(out) - np.asarray(ref))
    rel = err.max() / (np.abs(np.asarray(ref)).max() + 1e-6)
    assert rel < 0.15, rel


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.floats(0.0, 1.0),
       st.sampled_from([(128, 128, 128), (256, 128, 128)]))
def test_property_dual_pass_equals_dense(seed, s, shape):
    """Property: for ANY int8 tensor, dual-pass == single dense pass."""
    m, k, n = shape
    x, w, asc, wsc = _mk_inputs(jax.random.PRNGKey(seed), m, k, n, s)
    a = encode(x)
    pop = tile_population(a.pbm, 128, 128)
    out = sparqle_matmul(a.lsb4, a.msb4, pop, w, asc, wsc)
    ref = quant_matmul_ref(x, w, asc, wsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("b,s,kvh,g,hd,bs", [
    (1, 256, 1, 2, 16, 128), (2, 512, 2, 4, 32, 256),
    (2, 512, 4, 1, 64, 512),
])
def test_kv4_decode_attention_allclose(b, s, kvh, g, hd, bs):
    """Fused packed-KV4 decode attention vs the dense dequantized oracle,
    swept over head groupings, head dims and cache blockings."""
    from repro.kernels.kv_attention import kv4_decode_attention
    from repro.kernels.ref import kv4_decode_attention_ref
    key = jax.random.PRNGKey(b * 100 + s)
    q = jax.random.normal(key, (b, kvh, g, hd))
    kq = jax.random.randint(jax.random.PRNGKey(1), (b, s, kvh, hd // 2),
                            -128, 128, jnp.int8)
    vq = jax.random.randint(jax.random.PRNGKey(2), (b, s, kvh, hd // 2),
                            -128, 128, jnp.int8)
    ks = jax.random.uniform(jax.random.PRNGKey(3), (b, s, kvh),
                            minval=0.1, maxval=1.0)
    vs = jax.random.uniform(jax.random.PRNGKey(4), (b, s, kvh),
                            minval=0.1, maxval=1.0)
    pos = jax.random.randint(jax.random.PRNGKey(5), (b,), 1, s,
                             dtype=jnp.int32)
    out = kv4_decode_attention(q, kq, ks, vq, vs, pos, bs=bs)
    ref = kv4_decode_attention_ref(q, kq, ks, vq, vs, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_kv4_decode_attention_matches_model_cache_format():
    """The kernel consumes exactly what model._kv_quant writes."""
    from repro.kernels.kv_attention import kv4_decode_attention
    from repro.kernels.ref import kv4_decode_attention_ref
    from repro.models.model import _kv_quant
    from repro.models.registry import SMOKES
    cfg = SMOKES["granite-8b"]  # kv_bits=4 packed
    b, s, kvh, hd = 2, 128, cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kvh
    k = jax.random.normal(jax.random.PRNGKey(0), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd))
    kq, ks = _kv_quant(cfg, k)
    vq, vs = _kv_quant(cfg, v)
    q = jax.random.normal(jax.random.PRNGKey(2), (b, kvh, g, hd))
    pos = jnp.full((b,), s - 1, jnp.int32)
    out = kv4_decode_attention(q, kq, ks, vq, vs, pos, bs=64)
    ref = kv4_decode_attention_ref(q, kq, ks, vq, vs, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_xla_and_pallas_backends_agree():
    x = jax.random.normal(jax.random.PRNGKey(9), (64, 192))
    wf = jax.random.normal(jax.random.PRNGKey(10), (192, 64)) * 0.2
    w = quantize_weights(wf, bits=4, axis=0)
    a = sparqle_linear(x, w, backend="pallas")
    b = sparqle_linear(x, w, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
