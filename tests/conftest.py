import os

# Tests run on the single real CPU device (the 512-device override is
# strictly dryrun.py's, per the assignment). Keep XLA single-threaded-ish
# and deterministic. The multi-device CI lane opts into more host devices
# with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set in the
# environment before this import); the `mesh` fixture below skips tests
# that need more devices than the run exposes.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def mesh():
    """Factory fixture: ``mesh(data=, model=)`` -> a ("data", "model")
    Mesh, or ``pytest.skip`` when the host exposes too few devices.

    Sharded-equivalence tests take this fixture so the default (1-device)
    tier-1 run skips them cleanly, while the `test-multidevice` CI lane —
    XLA_FLAGS=--xla_force_host_platform_device_count=8 — runs them for
    real. Skipping (not erroring) is deliberate: device count is an
    environment property, not a test failure.
    """
    from repro.launch.mesh import make_smoke_mesh

    def make(data: int = 1, model: int = 1):
        need = data * model
        have = len(jax.devices())
        if need > have:
            pytest.skip(
                f"needs {need} devices, have {have}; run with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
        return make_smoke_mesh(data=data, model=model)

    return make
