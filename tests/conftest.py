import os

# Tests run on the single real CPU device (the 512-device override is
# strictly dryrun.py's, per the assignment). Keep XLA single-threaded-ish
# and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
