"""Per-architecture smoke tests (assignment deliverable f) + model-level
SPARQLe integration: every arch instantiates a reduced config, runs one
forward and one train step on CPU, asserts shapes and no NaNs; decode
matches full forward; quantized serving agrees with float."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.launch import steps as S
from repro.models import model as M
from repro.models.registry import ARCHS, SMOKES, cell_plan
from repro.models.schema import init_params, param_count
from repro.models.schema_builder import build_schema
from repro.optim.adamw import OptConfig, init_opt_state

ALL = sorted(SMOKES)

# heavy smoke archs (deep scans / MoE dispatch / SSD hybrids): several
# compile-minutes each -> excluded from the CI fast job via @slow
SLOW_ARCHS = {"jamba-v0.1-52b", "deepseek-v3-671b", "deepseek-moe-16b",
              "gemma3-27b"}


def _mark_slow(names):
    return [pytest.param(n, marks=pytest.mark.slow) if n in SLOW_ARCHS
            else n for n in names]



def _batch(cfg: ModelConfig, b=2, s=24, key=0, train=True):
    k = jax.random.PRNGKey(key)
    out = {}
    if cfg.family == "encoder":
        out["frames"] = jax.random.normal(
            k, (b, s, cfg.d_model)).astype(cfg.cdtype)
        tgt = s
    elif cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k, (b, cfg.n_prefix, cfg.d_model)).astype(cfg.cdtype)
        out["tokens"] = jax.random.randint(k, (b, s - cfg.n_prefix), 0,
                                           cfg.vocab)
        tgt = s - cfg.n_prefix
    else:
        out["tokens"] = jax.random.randint(k, (b, s), 0, cfg.vocab)
        tgt = s
    if train:
        out["targets"] = jax.random.randint(k, (b, tgt), 0, cfg.vocab)
    return out


@pytest.mark.parametrize("name", _mark_slow(ALL))
def test_smoke_forward(name):
    cfg = SMOKES[name]
    params = init_params(build_schema(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg, train=False)
    logits = M.forward(cfg, params, batch)
    b = 2
    s = 24 if cfg.family != "vlm" else 24
    assert logits.shape == (b, s, cfg.vocab)
    assert not np.isnan(np.asarray(logits, dtype=np.float32)).any()


@pytest.mark.parametrize("name", _mark_slow(ALL))
def test_smoke_train_step(name):
    cfg = SMOKES[name]
    params = init_params(build_schema(cfg), jax.random.PRNGKey(0))
    ocfg = OptConfig(warmup_steps=1, total_steps=4)
    step = jax.jit(S.make_train_step(cfg, ocfg,
                                     S.TrainKnobs(microbatch=0, ce_chunk=8)))
    state = S.TrainState(params, init_opt_state(params, ocfg))
    batch = _batch(cfg)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    state, m2 = step(state, batch)
    assert float(m2["loss"]) < float(m["loss"]) + 1.0  # no blow-up


@pytest.mark.parametrize("name", _mark_slow(
    [n for n in ALL if SMOKES[n].family not in ("encoder",)]))
def test_smoke_decode_consistency(name):
    """prefill + decode == forward on the extended sequence (tight KV)."""
    cfg = SMOKES[name].replace(kv_bits=8)
    params = init_params(build_schema(cfg), jax.random.PRNGKey(0))
    B, Ss, NEW = 2, 16, 3
    batch = _batch(cfg, b=B, s=Ss, train=False)
    toks = batch.get("tokens")
    lg_pre, cache = M.prefill(cfg, params, batch, max_len=Ss + NEW)
    new = jax.random.randint(jax.random.PRNGKey(5), (B, NEW), 0, cfg.vocab)
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([toks, new], 1)
    ref = M.forward(cfg, params, ext)
    outs = [lg_pre]
    for t in range(NEW):
        pos = jnp.full((B,), Ss + t, jnp.int32)
        lg, cache = M.decode_step(cfg, params, cache, new[:, t], pos)
        outs.append(lg)
    dec = jnp.stack(outs, 1).astype(jnp.float32)
    refl = ref[:, Ss - 1:Ss + NEW].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(dec - refl)) /
                (jnp.max(jnp.abs(refl)) + 1e-9))
    # loose: bf16 step-vs-batch accumulation differences compound through
    # MoE top-k routing and SSD state updates (jamba sits near the line,
    # and the exact value shifts with the XLA version)
    assert rel < 0.12, rel


@pytest.mark.parametrize("name", _mark_slow(
    ["granite-8b", "deepseek-moe-16b", "jamba-v0.1-52b", "mamba2-2.7b"]))
def test_smoke_sparqle_serving(name):
    """SPARQLe-served forward: close to float where the architecture
    permits, and ALWAYS exactly equal to the dense-quantized mode (the
    decomposition identity at model level)."""
    cfg = SMOKES[name]
    params = init_params(build_schema(cfg), jax.random.PRNGKey(0))
    qp = quantize_model_params(params, w_bits=cfg.w_bits, tile_k=16)
    batch = _batch(cfg, train=False)
    lf = M.forward(cfg, params, batch).astype(jnp.float32)
    lq = M.forward(cfg, qp, batch).astype(jnp.float32)
    assert not np.isnan(np.asarray(lq)).any()
    cos = float((lf * lq).sum() /
                (jnp.linalg.norm(lf) * jnp.linalg.norm(lq) + 1e-9))
    if cfg.family == "hybrid":
        # random-init SSD recurrence + router flips amplify W4A8 error
        # (the paper's §3.2 error-propagation caveat); trained-model
        # accuracy is covered by benchmarks/bench_accuracy.py
        assert cos > 0.5, cos
    else:
        assert cos > 0.9, cos
    # decomposition identity: sparqle mode == dense quantized mode
    qp_dense = quantize_model_params(params, w_bits=cfg.w_bits,
                                     tile_k=16, mode="dense")
    ld = M.forward(cfg, qp_dense, batch).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(ld),
                               rtol=2e-2, atol=2e-2)


def test_full_config_param_counts():
    """The FULL configs hit their nominal parameter counts (structure is
    faithful to the assignment table) — via schema, no allocation."""
    expected = {
        "starcoder2-3b": (2.8, 3.6), "granite-8b": (7.0, 9.0),
        "gemma3-27b": (24, 30), "yi-6b": (5.5, 6.6),
        "hubert-xlarge": (0.8, 1.1), "jamba-v0.1-52b": (47, 56),
        "deepseek-v3-671b": (640, 700), "deepseek-moe-16b": (15, 18),
        "paligemma-3b": (2.2, 3.2), "mamba2-2.7b": (2.4, 3.0),
    }
    for name, (lo, hi) in expected.items():
        n = param_count(build_schema(ARCHS[name])) / 1e9
        assert lo <= n <= hi, (name, n)


def test_cell_plan_covers_40():
    total = runs = 0
    for name in ARCHS:
        for _, ok, why in cell_plan(name):
            total += 1
            runs += ok
            if not ok:
                assert why
    assert total == 40 and runs == 32


@pytest.mark.parametrize("name", ALL)
def test_stage_plans_cover_layers(name):
    from repro.models.stages import build_stages, total_layers
    cfg = ARCHS[name]
    assert total_layers(build_stages(cfg)) == cfg.n_layers
