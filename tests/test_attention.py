"""Attention-path oracles: blockwise flash vs naive masked attention,
absorbed MLA vs explicitly materialized K/V, sliding windows, prefix-LM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.models.layers import AttnSpec, NEG_INF, flash_attention
from repro.models.model import _mla_flash


def naive_attention(q, k, v, allow):
    """Reference: full (Sq, Skv) score matrix, f32."""
    b, sq, h, hd = q.shape
    _, skv, kvh, hdv = k.shape[0], k.shape[1], k.shape[2], v.shape[-1]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    s = jnp.einsum("bikgd,bjkd->bkgij", qg, k.astype(jnp.float32))
    s = s * hd ** -0.5
    s = jnp.where(allow[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hdv)


def _qkv(key, b=2, s=32, h=4, kvh=2, hd=16, hdv=None):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, s, h, hd))
    k = jax.random.normal(k2, (b, s, kvh, hd))
    v = jax.random.normal(k3, (b, s, kvh, hdv or hd))
    return q, k, v


@pytest.mark.parametrize("bq,bkv", [(8, 8), (16, 32), (32, 16)])
def test_flash_matches_naive_causal(bq, bkv):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    spec = AttnSpec(causal=True)
    out = flash_attention(q, k, v, spec, bq=bq, bkv=bkv)
    i = jnp.arange(32)
    allow = i[None, :] <= i[:, None]
    ref = naive_attention(q, k, v, allow)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_sliding_window():
    q, k, v = _qkv(jax.random.PRNGKey(1))
    w = 5
    out = flash_attention(q, k, v, AttnSpec(causal=True, window=w),
                          bq=8, bkv=8)
    i = jnp.arange(32)
    allow = (i[None, :] <= i[:, None]) & ((i[:, None] - i[None, :]) < w)
    ref = naive_attention(q, k, v, allow)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_prefix_lm():
    """paligemma: bidirectional prefix, causal suffix."""
    q, k, v = _qkv(jax.random.PRNGKey(2))
    p = 8
    out = flash_attention(q, k, v, AttnSpec(causal=True, prefix_len=p),
                          bq=8, bkv=8)
    i = jnp.arange(32)
    allow = (i[None, :] <= i[:, None]) | (i[None, :] < p)
    ref = naive_attention(q, k, v, allow)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_bidirectional_encoder():
    q, k, v = _qkv(jax.random.PRNGKey(3))
    out = flash_attention(q, k, v, AttnSpec(causal=False), bq=8, bkv=16)
    allow = jnp.ones((32, 32), bool)
    ref = naive_attention(q, k, v, allow)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_separate_v_dim():
    q, k, v = _qkv(jax.random.PRNGKey(4), hdv=24)
    out = flash_attention(q, k, v, AttnSpec(causal=True), bq=8, bkv=8)
    assert out.shape == (2, 32, 4, 24)
    i = jnp.arange(32)
    ref = naive_attention(q, k, v, i[None, :] <= i[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_flash_block_size_invariance(seed):
    """The output must not depend on the blocking."""
    q, k, v = _qkv(jax.random.PRNGKey(seed))
    spec = AttnSpec(causal=True)
    a = flash_attention(q, k, v, spec, bq=8, bkv=8)
    b = flash_attention(q, k, v, spec, bq=32, bkv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# absorbed MLA vs materialized reference
# ---------------------------------------------------------------------------

def test_mla_absorbed_equals_materialized():
    """The weight-absorbed blockwise MLA must equal attention over the
    explicitly expanded K/V (the correctness of DESIGN.md's MLA rewrite)."""
    key = jax.random.PRNGKey(0)
    b, s, H, dn, dr, dv, rkv = 2, 24, 4, 8, 4, 6, 16
    qn = jax.random.normal(key, (b, s, H, dn))
    qr = jax.random.normal(jax.random.PRNGKey(1), (b, s, H, dr))
    ckv = jax.random.normal(jax.random.PRNGKey(2), (b, s, rkv))
    kr = jax.random.normal(jax.random.PRNGKey(3), (b, s, dr))
    w_uk = jax.random.normal(jax.random.PRNGKey(4), (rkv, H, dn)) * 0.3
    w_uv = jax.random.normal(jax.random.PRNGKey(5), (rkv, H, dv)) * 0.3

    out = _mla_flash(qn, qr, ckv, kr, w_uk, w_uv, causal=True,
                     bq=8, bkv=8)

    # reference: materialize per-head K = [k_nope; k_rope], V
    k_nope = jnp.einsum("bsr,rhd->bshd", ckv, w_uk)
    v = jnp.einsum("bsr,rhd->bshd", ckv, w_uv)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (b, s, H, dr))], -1)
    q_full = jnp.concatenate([qn, qr], -1)
    i = jnp.arange(s)
    ref = naive_attention(q_full, k_full, v, i[None, :] <= i[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_mla_flash_pad_invariance():
    """Non-divisible sequence lengths (MTP's S-1) pad internally."""
    key = jax.random.PRNGKey(7)
    b, s, H, dn, dr, dv, rkv = 1, 13, 2, 8, 4, 6, 16
    qn = jax.random.normal(key, (b, s, H, dn))
    qr = jax.random.normal(jax.random.PRNGKey(1), (b, s, H, dr))
    ckv = jax.random.normal(jax.random.PRNGKey(2), (b, s, rkv))
    kr = jax.random.normal(jax.random.PRNGKey(3), (b, s, dr))
    w_uk = jax.random.normal(jax.random.PRNGKey(4), (rkv, H, dn)) * 0.3
    w_uv = jax.random.normal(jax.random.PRNGKey(5), (rkv, H, dv)) * 0.3
    a = _mla_flash(qn, qr, ckv, kr, w_uk, w_uv, causal=True, bq=8, bkv=8)
    full = _mla_flash(qn, qr, ckv, kr, w_uk, w_uv, causal=True,
                      bq=13, bkv=13)
    np.testing.assert_allclose(np.asarray(a), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
