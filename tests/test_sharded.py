"""Sharded-vs-single-device bit-exactness at the kernel/op level.

The tensor-parallel serving path (distributed/tp.py, docs/sharding.md)
claims BIT-exact equality with the single-device kernels, not closeness:
column partitions compute untouched output slices, and row partitions
reduce the merged int32 dual-pass accumulator with one psum (integer
addition is associative) after an exact global pmax for the per-token
scale. Every test here asserts array_equal, never allclose.

All tests take the `mesh` fixture and skip when the host exposes too few
devices; the CI `test-multidevice` lane runs them on 8 forced CPU
devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.quantize import quantize_weights
from repro.distributed.tp import shard_map_compat
from repro.kernels.ops import sparqle_linear, sparqle_linear_sharded
from repro.kernels.sparqle_encode import sparqle_encode
from repro.kernels.sparqle_matmul import sparqle_matmul


def _operands(m=8, k=64, n=32, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    w = quantize_weights(jnp.asarray(rng.randn(k, n).astype(np.float32)),
                         bits=4, axis=0)
    mask = jnp.asarray(rng.rand(k) < 0.5)
    return x, w, mask


@pytest.mark.parametrize("ways", [2, 4])
@pytest.mark.parametrize("wire_format", ["unpacked", "packed"])
@pytest.mark.parametrize("msb_skip", [False, True])
@pytest.mark.parametrize("partition", ["col", "row"])
def test_sparqle_linear_sharded_bit_exact(mesh, ways, wire_format,
                                          msb_skip, partition):
    """Both wire formats and the msb_skip draft dispatch, col and row
    partitioned 2- and 4-way, against the unsharded Pallas kernel."""
    m = mesh(model=ways)
    x, w, col_mask = _operands()
    kw = dict(col_mask=col_mask, clip_l=jnp.float32(-8.0),
              clip_h=jnp.float32(23.0), wire_format=wire_format,
              msb_skip=msb_skip, bm=8, bn=8, bk=16)
    ref = sparqle_linear(x, w, **kw)
    got = sparqle_linear_sharded(x, w, mesh=m, axis="model",
                                 partition=partition, **kw)
    assert got.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_sparqle_linear_sharded_no_clipping(mesh):
    m = mesh(model=2)
    x, w, _ = _operands(seed=3)
    ref = sparqle_linear(x, w, bm=8, bn=8, bk=16)
    got = sparqle_linear_sharded(x, w, mesh=m, partition="row",
                                 bm=8, bn=8, bk=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_row_sharded_is_single_psum_of_merged_acc(mesh):
    """The row partition reduces ONE merged int32 accumulator: kernel
    acc_out (LSB + shifted MSB already summed) psum'd across shards must
    reproduce the full-K accumulator bit for bit."""
    from repro.core.sparqle import encode, tile_population
    m = mesh(model=2)
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randint(-128, 128, (8, 32)), jnp.int8)
    wq = jnp.asarray(rng.randint(-8, 8, (32, 16)), jnp.int8)
    ones_a = jnp.ones((8, 1), jnp.float32)
    ones_w = jnp.ones((1, 16), jnp.float32)

    def full_acc(qv, wv):
        act = encode(qv, 1.0)
        pop = tile_population(act.pbm, 8, 16)
        return sparqle_matmul(act.lsb4, act.msb4, pop, wv, ones_a, ones_w,
                              bm=8, bn=16, bk=16, acc_out=True)

    ref = full_acc(q, wq)
    assert ref.dtype == jnp.int32

    def body(qv, wv):
        return jax.lax.psum(full_acc(qv, wv), "model")

    fn = shard_map_compat(body, m, in_specs=(P(None, "model"),
                                             P("model", None)),
                          out_specs=P(None, None))
    np.testing.assert_array_equal(np.asarray(fn(q, wq)), np.asarray(ref))


def test_acc_out_matches_rescaled_output():
    """acc_out * scales == the kernel's own drain-path rescale (runs on
    any device count — no mesh needed)."""
    from repro.core.sparqle import encode, tile_population
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randint(-128, 128, (8, 32)), jnp.int8)
    wq = jnp.asarray(rng.randint(-8, 8, (32, 16)), jnp.int8)
    asc = jnp.asarray(np.abs(rng.randn(8, 1)) + 0.1, jnp.float32)
    wsc = jnp.asarray(np.abs(rng.randn(1, 16)) + 0.1, jnp.float32)
    act = encode(q, 1.0)
    pop = tile_population(act.pbm, 8, 16)
    out = sparqle_matmul(act.lsb4, act.msb4, pop, wq, asc, wsc,
                         bm=8, bn=16, bk=16)
    acc = sparqle_matmul(act.lsb4, act.msb4, pop, wq, asc, wsc,
                         bm=8, bn=16, bk=16, acc_out=True)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(acc.astype(jnp.float32) * asc * wsc))


@pytest.mark.parametrize("ways", [2, 4])
def test_sparqle_encode_sharded_rows_bit_exact(mesh, ways):
    """The drain-path encoder is per-row: sharding M over the mesh must
    reproduce every plane and tile population exactly."""
    m = mesh(model=ways)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(16, 32).astype(np.float32))
    scale = jnp.asarray(np.abs(rng.randn(16, 1)) + 0.05, jnp.float32)
    ref = sparqle_encode(x, scale, bm=4, bk=32)

    def body(xv, sv):
        return sparqle_encode(xv, sv, bm=4, bk=32)

    fn = shard_map_compat(
        body, m,
        in_specs=(P("model", None), P("model", None)),
        out_specs=(P("model", None), P("model", None), P("model", None),
                   P("model", None)))
    got = fn(x, scale)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))


@pytest.mark.parametrize("ways", [2, 4])
def test_paged_decode_attention_kv_head_sharded(mesh, ways):
    """kv4_paged_decode_attention with KV heads sharded over the model
    axis: every shard runs the identical flash-decoding body on its head
    slice, so the assembled output is bit-exact."""
    from repro.kernels.kv_attention import kv4_paged_decode_attention
    m = mesh(model=ways)
    b, kvh, g, hd, npages, ps, nsteps = 2, 4, 2, 8, 6, 4, 3
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, kvh, g, hd), jnp.float32)
    kq = jnp.asarray(rng.randint(-128, 128, (npages, ps, kvh, hd // 2)),
                     jnp.int8)
    ks = jnp.asarray(np.abs(rng.randn(npages, ps, kvh)) + 0.1, jnp.float32)
    vq = jnp.asarray(rng.randint(-128, 128, (npages, ps, kvh, hd // 2)),
                     jnp.int8)
    vs = jnp.asarray(np.abs(rng.randn(npages, ps, kvh)) + 0.1, jnp.float32)
    bt = jnp.asarray(rng.randint(0, npages, (b, nsteps)), jnp.int32)
    pos = jnp.asarray([5, 9], jnp.int32)

    ref = kv4_paged_decode_attention(q, kq, ks, vq, vs, bt, pos)

    fn = shard_map_compat(
        kv4_paged_decode_attention, m,
        in_specs=(P(None, "model"), P(None, None, "model"),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, None, "model"), P(None, None), P(None)),
        out_specs=P(None, "model"))
    np.testing.assert_array_equal(np.asarray(fn(q, kq, ks, vq, vs, bt,
                                                pos)),
                                  np.asarray(ref))


def test_paged_verify_attention_kv_head_sharded(mesh):
    """Multi-token verify attention shards the same way (window axis
    complete on every shard)."""
    from repro.kernels.kv_attention import kv4_paged_verify_attention
    m = mesh(model=2)
    b, t, kvh, g, hd, npages, ps, nsteps = 2, 3, 2, 2, 8, 6, 4, 3
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(b, t, kvh, g, hd), jnp.float32)
    kq = jnp.asarray(rng.randint(-128, 128, (npages, ps, kvh, hd // 2)),
                     jnp.int8)
    ks = jnp.asarray(np.abs(rng.randn(npages, ps, kvh)) + 0.1, jnp.float32)
    vq = jnp.asarray(rng.randint(-128, 128, (npages, ps, kvh, hd // 2)),
                     jnp.int8)
    vs = jnp.asarray(np.abs(rng.randn(npages, ps, kvh)) + 0.1, jnp.float32)
    bt = jnp.asarray(rng.randint(0, npages, (b, nsteps)), jnp.int32)
    pos = jnp.asarray([4, 7], jnp.int32)

    ref = kv4_paged_verify_attention(q, kq, ks, vq, vs, bt, pos)

    fn = shard_map_compat(
        kv4_paged_verify_attention, m,
        in_specs=(P(None, None, "model"), P(None, None, "model"),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, None, "model"), P(None, None), P(None)),
        out_specs=P(None, None, "model"))
    np.testing.assert_array_equal(np.asarray(fn(q, kq, ks, vq, vs, bt,
                                                pos)),
                                  np.asarray(ref))


def test_smoke_mesh_error_names_xla_flags():
    """make_smoke_mesh must fail with an actionable message (naming the
    XLA_FLAGS workaround), never a bare jax shape error."""
    import jax as _jax
    from repro.launch.mesh import make_smoke_mesh
    too_many = len(_jax.devices()) + 1
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        make_smoke_mesh(data=too_many, model=1)
