"""Self-speculative decoding: pool truncation, msb_skip draft kernels,
multi-token verify bit-exactness, and spec-engine == base-engine token
equivalence at temperature 0 (plus rejection-sampling termination)."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import packing as packing_lib
from repro.core.qlinear import (_dual_pass_matmul, msb_skip_active,
                                msb_skip_scope, quantize_model_params)
from repro.core.sparqle import encode, tile_population
from repro.models import model as M
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.serving import (Engine, PagedKVPool, PoolConfig, SamplingParams,
                           Scheduler, SchedulerConfig, SpecConfig,
                           SpeculativeEngine)

CFG = ModelConfig(name="tiny-serve", family="transformer", n_layers=2,
                  d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                  d_ff=64, vocab=128, dtype="float32")

# a second paged-supported config with a different scanned period (MoE
# every 2nd layer) — the "≥ 2 model configs" of the acceptance criteria
CFG_MOE = ModelConfig(name="tiny-moe-serve", family="moe", n_layers=4,
                      d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_ff=64, vocab=64, dtype="float32", n_experts=4,
                      top_k=2, moe_every=2, moe_d_ff=32,
                      router_type="softmax")


def _qparams(cfg, seed=0):
    fp = init_params(build_schema(cfg), jax.random.PRNGKey(seed))
    return quantize_model_params(
        fp, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)


@pytest.fixture(scope="module")
def qparams():
    return _qparams(CFG)


# ---------------------------------------------------------------------------
# PagedKVPool.truncate
# ---------------------------------------------------------------------------

def test_truncate_page_boundary_and_mid_page():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    pages = pool.allocate(5, "r")                    # covers 20 tokens
    # page boundary: 8 tokens -> keep exactly 2 pages
    freed = pool.truncate("r", 8)
    assert freed == pages[2:]
    assert pool.pages_of("r") == pages[:2]
    # mid-page: 5 tokens -> a partially-filled page 2 is kept whole
    pool.allocate(3, "r")
    assert len(pool.pages_of("r")) == 5
    kept_before = pool.pages_of("r")
    freed = pool.truncate("r", 5)
    assert freed == kept_before[2:]
    assert pool.pages_of("r") == kept_before[:2]
    # truncating past the held range is a no-op
    assert pool.truncate("r", 100) == []
    assert pool.pages_of("r") == kept_before[:2]


def test_truncate_preserves_ownership_and_eviction_counters():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    fired = []
    pool.on_evict = lambda owner, pgs: fired.append(owner)
    a = pool.allocate(3, "a")
    pool.allocate(2, "b")
    freed = pool.truncate("a", 4)                    # keep 1 page of a
    assert freed == a[1:]
    assert pool.evictions == 0 and fired == []       # not an eviction
    assert pool.pages_of("a") == a[:1]               # prefix order kept
    assert len(pool.pages_of("b")) == 2              # b untouched
    # freed pages are back in the free pool (FIFO: grab everything)
    c = pool.allocate(pool.num_free, "c")
    assert set(a[1:]) <= set(c)


def test_truncate_to_zero_removes_ownership_entry():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    pages = pool.allocate(2, "r")
    assert pool.truncate("r", 0) == pages
    assert "r" not in pool._owned                    # no phantom owner
    assert pool.evict("r") == [] and pool.evictions == 0
    # unknown owner / negative count
    assert pool.truncate("ghost", 4) == []
    with pytest.raises(ValueError):
        pool.truncate("r", -1)


# ---------------------------------------------------------------------------
# msb_skip draft matmul == dequantizing the LSB plane alone
# ---------------------------------------------------------------------------

def test_msb_skip_matmul_exhaustive_nibbles():
    """All 256 int8 values through both kernel layouts with msb_skip: the
    output must equal the LSB4 plane's contribution alone (the
    acceptance-criterion sweep for the draft path)."""
    from repro.kernels.sparqle_matmul import (sparqle_matmul,
                                              sparqle_matmul_packed)
    x = jnp.arange(-128, 128, dtype=jnp.int8).reshape(2, 128).repeat(64, 0)
    w = jax.random.randint(jax.random.PRNGKey(1), (128, 128), -8, 8,
                           dtype=jnp.int8)
    asc = jnp.ones((128, 1)); wsc = jnp.ones((1, 128))
    a = encode(x)
    pop = tile_population(a.pbm, 128, 128)
    # oracle: dequantized LSB plane (values 0..15) times the weights
    ref = jnp.dot(a.lsb4.astype(jnp.int32),
                  w.astype(jnp.int32)).astype(jnp.float32)
    out = sparqle_matmul(a.lsb4, a.msb4, pop, w, asc, wsc, msb_skip=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    outp = sparqle_matmul_packed(
        packing_lib.pack_nibbles(a.lsb4), packing_lib.pack_nibbles(a.msb4),
        pop, w, asc, wsc, msb_skip=True)
    np.testing.assert_array_equal(np.asarray(outp), np.asarray(ref))


def test_msb_skip_ops_linear_and_xla_backend():
    from repro.core.quantize import quantize_weights
    from repro.kernels.ops import sparqle_linear
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 192))
    w = quantize_weights(
        jax.random.normal(jax.random.PRNGKey(3), (192, 96)) * 0.1,
        bits=4, axis=0)
    a = sparqle_linear(x, w, backend="pallas", msb_skip=True)
    b = sparqle_linear(x, w, backend="xla", msb_skip=True)
    full = sparqle_linear(x, w, backend="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
    assert np.abs(np.asarray(a) - np.asarray(full)).max() > 0


def test_msb_skip_scope_drives_dual_pass():
    """qlinear's trace-time scope: inside the scope the dual-pass matmul
    returns the dense LSB4 contribution alone (both wire formats)."""
    x = jnp.arange(-128, 128, dtype=jnp.int8).reshape(4, 64)
    w = jax.random.randint(jax.random.PRNGKey(5), (64, 32), -8, 8,
                           dtype=jnp.int8)
    a = encode(x)
    lsb_ref = jnp.dot(a.lsb4.astype(jnp.int32), w.astype(jnp.int32))
    assert not msb_skip_active()
    with msb_skip_scope():
        assert msb_skip_active()
        for wf in ("unpacked", "packed"):
            out = _dual_pass_matmul(x, w, batched=False, wire_format=wf,
                                    msb_skip=True)
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(lsb_ref))
    assert not msb_skip_active()


# ---------------------------------------------------------------------------
# multi-token verify attention == loop of single-token paged decodes
# ---------------------------------------------------------------------------

def test_verify_attention_bitexact_vs_single_token_loop():
    from repro.kernels.kv_attention import (kv4_paged_decode_attention,
                                            kv4_paged_verify_attention)
    b, s, kvh, g, hd, ps, t = 2, 64, 2, 4, 32, 16, 3
    kq = jax.random.randint(jax.random.PRNGKey(1), (b, s, kvh, hd // 2),
                            -128, 128, jnp.int8)
    vq = jax.random.randint(jax.random.PRNGKey(2), (b, s, kvh, hd // 2),
                            -128, 128, jnp.int8)
    ks = jax.random.uniform(jax.random.PRNGKey(3), (b, s, kvh),
                            minval=0.1, maxval=1.0)
    vs = jax.random.uniform(jax.random.PRNGKey(4), (b, s, kvh),
                            minval=0.1, maxval=1.0)
    pos = jnp.asarray([5, 40], jnp.int32)
    n_per = s // ps
    # shuffled physical pages
    perm = np.random.RandomState(0).permutation(b * n_per) + 1
    kp = np.zeros((b * n_per + 1, ps, kvh, hd // 2), np.int8)
    vp = np.zeros_like(kp)
    ksp = np.zeros((b * n_per + 1, ps, kvh), np.float32)
    vsp = np.zeros_like(ksp)
    bt = np.zeros((b, n_per), np.int32)
    for i in range(b):
        for j in range(n_per):
            pid = int(perm[i * n_per + j])
            bt[i, j] = pid
            sl = slice(j * ps, (j + 1) * ps)
            kp[pid], vp[pid] = kq[i, sl], vq[i, sl]
            ksp[pid], vsp[pid] = ks[i, sl], vs[i, sl]
    args = (jnp.asarray(kp), jnp.asarray(ksp), jnp.asarray(vp),
            jnp.asarray(vsp), jnp.asarray(bt))
    qT = jax.random.normal(jax.random.PRNGKey(7), (b, t, kvh, g, hd))
    out = kv4_paged_verify_attention(qT, *args, pos)
    for i in range(t):
        single = kv4_paged_decode_attention(qT[:, i], *args, pos + i)
        np.testing.assert_array_equal(np.asarray(out[:, i]),
                                      np.asarray(single))


@pytest.mark.slow
@pytest.mark.parametrize("cfg", [
    CFG,
    # tight expert capacity: routed-MoE drops depend on the flat token
    # count, so this would diverge if the verify window batched all B*T
    # tokens into one dispatch instead of one call per window position
    CFG_MOE.replace(capacity_factor=0.5),
], ids=["dense", "moe-tight-capacity"])
def test_verify_window_paged_equals_decode_loop(cfg):
    """The full model-level verify window — logits AND written pool state —
    must reproduce a loop of single-token paged decode steps."""
    qp = _qparams(cfg)
    pool = PagedKVPool(cfg, PoolConfig(n_pages=8, page_size=4))
    pages = pool.allocate(4, "r")
    bt = np.zeros((2, 6), np.int32)
    bt[0, :4] = pages
    bt = jnp.asarray(bt)
    prompt = np.random.RandomState(0).randint(0, cfg.vocab, size=5)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    lg, st, _ = M.prefill_chunk_paged(
        cfg, qp, pool.state, jnp.pad(toks, ((0, 0), (0, 3))),
        jnp.asarray(0, jnp.int32), jnp.asarray(5, jnp.int32), bt[:1])
    window = jnp.asarray([[int(jnp.argmax(lg, -1)[0]), 17, 42],
                          [3, 1, 4]], jnp.int32)
    pos = jnp.asarray([5, 0], jnp.int32)
    vlg, vstate, vtel = M.verify_window_paged(cfg, qp, st, window, pos, bt)
    st2 = st
    for t in range(3):
        lg1, st2, _ = M.decode_step_paged(cfg, qp, st2, window[:, t],
                                          pos + t, bt)
        np.testing.assert_array_equal(np.asarray(vlg[:, t]),
                                      np.asarray(lg1))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        vstate, st2)
    assert vtel["layer_wire_bytes"].shape == (cfg.n_layers, 2)


# ---------------------------------------------------------------------------
# scheduler accounting for draft windows
# ---------------------------------------------------------------------------

def test_scheduler_spec_budget_and_lookahead():
    """A speculative decode slot burns 2γ+1 budget tokens, pages grow to
    cover the draft window, and admission reserves the lookahead."""
    pool = PagedKVPool(CFG, PoolConfig(n_pages=32, page_size=4))
    sched = Scheduler(pool, SchedulerConfig(
        max_decode_batch=4, token_budget=10, prefill_chunk=8,
        max_pages_per_seq=8, decode_tokens_per_slot=5, decode_lookahead=2))
    a = sched.submit([1] * 4, SamplingParams(max_new_tokens=4), 0.0)
    pool.allocate(1, a.rid)
    a.prefilled = len(a.context)
    a.slot = sched._free_slots.pop(0)
    a.context.append(9)
    a.out_tokens.append(9)
    sched.to_running(a)
    b = sched.submit([2] * 20, SamplingParams(max_new_tokens=4), 1.0)
    plan = sched.schedule()
    assert plan.decode == [a]
    # pages cover pos + 1 + lookahead = 4 + 1 + 2 = 7 tokens -> 2 pages
    assert len(pool.pages_of(a.rid)) == 2
    # budget 10 - 1 slot * 5 = 5 -> b's chunk is capped at 5, not 8
    assert [(r.rid, start, n) for r, start, n in plan.prefill] == \
        [(b.rid, 0, 5)]
    # admission capacity reserves the lookahead: 8 pages * 4 = 32 slots;
    # 30 + 4 + lookahead 2 > 32 must be rejected
    with pytest.raises(ValueError):
        sched.submit([0] * 30, SamplingParams(max_new_tokens=4), 2.0)


# ---------------------------------------------------------------------------
# speculative engine vs base engine
# ---------------------------------------------------------------------------

def _run_engines(cfg, qp, prompts, gen, gamma, temperature=0.0):
    def mk(spec):
        kw = dict(
            pool_config=PoolConfig(n_pages=32, page_size=4),
            sched_config=SchedulerConfig(max_decode_batch=4,
                                         token_budget=64, prefill_chunk=32,
                                         max_pages_per_seq=16))
        if spec:
            return SpeculativeEngine(cfg, qp, spec=SpecConfig(gamma=gamma),
                                     **kw)
        return Engine(cfg, qp, **kw)

    outs = []
    for spec in (False, True):
        eng = mk(spec)
        hs = [eng.submit(p, SamplingParams(max_new_tokens=gen,
                                           temperature=temperature))
              for p in prompts]
        eng.run()
        outs.append((eng, hs))
    return outs


@pytest.mark.slow
@pytest.mark.parametrize("cfg,seed", [(CFG, 0), (CFG_MOE, 1)])
def test_spec_engine_greedy_matches_base_engine(cfg, seed):
    """Temperature-0 speculative decoding is byte-identical to the
    non-speculative engine across two model configs (the correctness
    anchor of the subsystem)."""
    qp = _qparams(cfg, seed)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab, size=n).tolist()
               for n in (12, 7, 19)]
    (base, base_hs), (spec, spec_hs) = _run_engines(cfg, qp, prompts,
                                                    gen=8, gamma=2)
    for hb, hs in zip(base_hs, spec_hs):
        assert hb.out_tokens == hs.out_tokens
        assert hs.n_generated == 8
        st = hs.stats()
        assert st["spec_tokens_per_step"] >= 1.0
        assert 0.0 <= st["spec_acceptance_rate"] <= 1.0
    # everything released after the speculative windows + truncations
    assert spec.pool.num_free == spec.pool.n_usable_pages
    agg = spec.aggregate_stats()
    assert agg["spec_gamma"] == 2
    assert agg["spec_tokens_per_step"] >= 1.0


@pytest.mark.slow
def test_spec_engine_rejection_sampling_terminates(qparams):
    """Temperature > 0 exercises the rejection-sampling acceptance path:
    every request terminates with exact n_generated accounting and sane
    draft bookkeeping."""
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, CFG.vocab, size=n).tolist() for n in (10, 5)]
    gen = 7
    (_, base_hs), (spec, spec_hs) = _run_engines(
        CFG, qparams, prompts, gen=gen, gamma=2, temperature=0.8)
    for h in spec_hs:
        assert h.done and h.n_generated == gen
        assert all(0 <= t < CFG.vocab for t in h.out_tokens)
        st = h.stats()
        assert 0.0 <= st["spec_acceptance_rate"] <= 1.0
        assert st["spec_tokens_per_step"] >= 1.0
        # every generated token after the prefill one came from a cycle
        assert h.spec_emitted == gen - 1
        assert h.draft_accepted <= h.draft_proposed
    assert spec.pool.num_free == spec.pool.n_usable_pages


@pytest.mark.slow
def test_spec_engine_draft_friendly_acceptance_band():
    """On the bench's draft-friendly model the LSB4-only draft is a real
    predictor: acceptance strictly inside (0, 1) and > 1 token per cycle,
    while the greedy stream still matches the non-speculative engine —
    i.e. the draft is genuinely sub-precision, not silently full."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import bench_serving as B
    cfg = B.BENCH_CFG
    fp = B.draft_friendly_params(cfg, seed=0)
    qp = quantize_model_params(
        fp, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=int(n)).tolist()
               for n in rng.randint(8, 24, 6)]
    (_, base_hs), (spec, spec_hs) = _run_engines(cfg, qp, prompts,
                                                 gen=10, gamma=2)
    for hb, hs in zip(base_hs, spec_hs):
        assert hb.out_tokens == hs.out_tokens
    agg = spec.aggregate_stats()
    assert 0.0 < agg["spec_acceptance_rate"] < 1.0
    assert agg["spec_tokens_per_step"] > 1.0


# ---------------------------------------------------------------------------
# cost model: speculative rounds
# ---------------------------------------------------------------------------

def test_costmodel_expected_tokens_and_draft_rounds():
    from repro.core.costmodel import (PAPER_MODELS, breakeven_acceptance,
                                      evaluate_speculative,
                                      expected_tokens_per_step)
    assert expected_tokens_per_step(0.0, 3) == 1.0
    assert expected_tokens_per_step(1.0, 3) == 4.0
    np.testing.assert_allclose(expected_tokens_per_step(0.5, 3), 1.875)
    with pytest.raises(ValueError):
        expected_tokens_per_step(1.5, 2)

    m = PAPER_MODELS["llama2-7b"]
    r = evaluate_speculative(m, 0.47, 2, 0.8)
    # the draft forward is 1 round vs 1 + (1 - s): strictly fewer MACs
    # (aggregate over the decode stack; act-act attention ops identical)
    assert r.draft_step.compute_macs < r.baseline_step.compute_macs
    # ... and strictly fewer streamed activation bytes
    assert r.draft_step.load_bytes < r.baseline_step.load_bytes
    # on a single eligible linear the ratio is exactly 1 / (2 - s)
    from repro.core.costmodel import HardwareConfig, LinearShape, linear_cost
    shape = LinearShape("l", 16, 4096, 4096, 4, 0.47)
    hw = HardwareConfig()
    full = linear_cost(shape, hw, sparqle=True)
    draft = linear_cost(shape, hw, sparqle=True, lsb_only=True)
    np.testing.assert_allclose(draft.compute_macs / full.compute_macs,
                               1.0 / (2.0 - 0.47))
    # E[tokens] amortization: speedup strictly increases with alpha
    speedups = [evaluate_speculative(m, 0.47, 2, a).tpot_speedup
                for a in (0.0, 0.5, 0.9)]
    assert speedups[0] < speedups[1] < speedups[2]
    # under the §4 restreaming dataflow the draft still pays the full
    # weight stream: at the paper's operating point γ-drafting cannot
    # win TPOT at any acceptance rate — the model says so honestly
    assert breakeven_acceptance(m, 0.47, 2) == float("inf")
