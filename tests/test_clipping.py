"""Sparsity enhancement (paper §3.2): importance, clipping, Algorithm 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.core.clipping import (apply_clipping,
                                 column_importance, enhanced_sparsity,
                                 global_calibrate, importance_mask,
                                 importance_mask_tile_aligned,
                                 init_clip_params, learn_clipping_constants,
                                 soft_clipping)
from repro.core.sparqle import subprecision_sparsity


def test_column_importance_is_weight_row_l1():
    w = jnp.array([[1.0, -2.0], [0.5, 0.5], [3.0, 0.0]])
    np.testing.assert_allclose(np.asarray(column_importance(w)),
                               [3.0, 1.0, 3.0])


def test_importance_mask_selects_k_least():
    w = jnp.diag(jnp.array([1.0, 2.0, 3.0, 4.0]))
    mask = importance_mask(w, 50.0)
    np.testing.assert_array_equal(np.asarray(mask),
                                  [True, True, False, False])


def test_tile_aligned_mask_selects_blocks():
    # 64 columns, tile 16 -> 4 blocks; make block 1 cheapest
    imp = jnp.ones((64, 8))
    imp = imp.at[16:32].set(0.01)
    mask = importance_mask_tile_aligned(imp, 25.0, 16)
    m = np.asarray(mask)
    assert m[16:32].all() and m[:16].sum() == 0 and m[32:].sum() == 0


def test_apply_clipping_semantics():
    """[l, 0) -> 0; (15, h] -> 15; outside [l, h] untouched; unmasked
    columns untouched — exactly Fig. 3."""
    x = jnp.array([[-10, -5, -1, 0, 15, 16, 20, 25]], dtype=jnp.int8)
    mask = jnp.ones((8,), bool)
    y = np.asarray(apply_clipping(x, mask, l=-5, h=20))
    np.testing.assert_array_equal(y[0], [-10, 0, 0, 0, 15, 15, 15, 25])
    # unmasked: nothing moves
    y2 = np.asarray(apply_clipping(x, jnp.zeros((8,), bool), -5, 20))
    np.testing.assert_array_equal(y2, np.asarray(x))


def test_clipping_increases_sparsity_monotonically():
    x = jax.random.randint(jax.random.PRNGKey(0), (256, 256), -128, 128,
                           dtype=jnp.int8)
    mask = jnp.ones((256,), bool)
    prev = float(subprecision_sparsity(x))
    for l, h in [(-4, 19), (-16, 31), (-64, 79)]:
        nat, enh = enhanced_sparsity(x, mask, l, h)
        assert float(nat) == pytest.approx(prev if l == -4 else float(nat))
        assert float(enh) >= prev
        prev = float(enh)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(-64, -1), st.integers(16, 90))
def test_property_clip_error_bounded(seed, l, h):
    """Every clipped value moves by at most max(|l|, h-15)."""
    x = jax.random.randint(jax.random.PRNGKey(seed), (64, 64), -128, 128,
                           dtype=jnp.int8)
    mask = jnp.ones((64,), bool)
    y = apply_clipping(x, mask, l, h)
    delta = np.abs(np.asarray(y).astype(int) - np.asarray(x).astype(int))
    assert delta.max() <= max(abs(l), h - 15)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_clip_idempotent(seed):
    x = jax.random.randint(jax.random.PRNGKey(seed), (32, 32), -128, 128,
                           dtype=jnp.int8)
    mask = jnp.ones((32,), bool)
    y1 = apply_clipping(x, mask, -8, 23)
    y2 = apply_clipping(y1, mask, -8, 23)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_soft_clipping_converges_to_hard():
    x = jnp.array([[-6, -3, 18, 30]], dtype=jnp.int8)
    mask = jnp.ones((4,), jnp.float32)
    l, h = jnp.float32(-5.0), jnp.float32(20.0)
    y_soft, _ = soft_clipping(x, mask, l, h, tau=0.01)
    y_hard = apply_clipping(x, mask.astype(bool), -5, 20)
    np.testing.assert_allclose(np.asarray(y_soft),
                               np.asarray(y_hard).astype(np.float32),
                               atol=0.1)


def test_soft_clipping_gradients_flow_to_lh():
    x = jax.random.randint(jax.random.PRNGKey(1), (64, 16), -128, 128,
                           dtype=jnp.int8)
    mask = jnp.ones((16,), jnp.float32)

    def f(lh):
        y, m = soft_clipping(x, mask, lh[0], lh[1], tau=2.0)
        return jnp.sum(y ** 2) * 1e-4 - jnp.mean(m)

    g = jax.grad(f)(jnp.array([-8.0, 23.0]))
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.any(np.asarray(g) != 0)


def test_global_calibrate_picks_tradeoff():
    # fake eval: wider range -> more sparsity, quadratically more error
    def eval_fn(l, h):
        width = (-l) + (h - 15)
        return float(width ** 2) * 1e-4, min(1.0, 0.3 + width * 0.01)

    res = global_calibrate(eval_fn, l_candidates=(-4, -16, -64),
                           h_candidates=(19, 31, 79), lam=10.0)
    # should not pick the most aggressive (error explodes) nor necessarily
    # the mildest; sanity: result is a real candidate with finite score
    assert res.l in (-4, -16, -64) and res.h in (19, 31, 79)
    assert res.l != -64 or res.h != 79  # most aggressive pair rejected


def test_algorithm1_learns_wider_bounds():
    """Eq. 3's sparsity reward should push (l, h) outward when error is
    cheap (identity-ish base model)."""
    key = jax.random.PRNGKey(0)
    data = jax.random.randint(key, (4, 32, 16), -40, 56, dtype=jnp.int8)
    mask = jnp.ones((16,), jnp.float32)

    def apply_clip(cp, batch):
        y, m = soft_clipping(batch, mask, cp["l"][0], cp["h"][0], tau=4.0)
        return y * 0.01, jnp.mean(m)

    def apply_base(batch):
        return batch.astype(jnp.float32) * 0.01

    cp0 = init_clip_params(1, l0=-1.0, h0=16.0)
    cp, hist = learn_clipping_constants(
        apply_clip, apply_base, data, cp0, epochs=23, lr=1.0, alpha=0.5)
    assert float(cp["l"][0]) < -1.0         # lower bound moved out
    assert float(cp["h"][0]) > 16.0         # upper bound moved out
    # learned constants clip MORE of a fixed batch than the initial ones
    _, m0 = apply_clip(cp0, data[0])
    _, m1 = apply_clip(cp, data[0])
    assert float(m1) > float(m0)
