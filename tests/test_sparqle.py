"""Core SPARQLe codec: exactness, Eq. 1/2, tile metadata (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.core.sparqle import (LP_HIGH, LP_LOW, compression_percent, decode,
                                encode, encoded_bytes, ops_reduction_percent,
                                subprecision_sparsity, tile_population,
                                tile_sparsity)


def test_roundtrip_all_int8_values():
    """encode/decode is the identity on every representable int8 value."""
    x = jnp.arange(-128, 128, dtype=jnp.int8).reshape(16, 16)
    a = encode(x)
    np.testing.assert_array_equal(np.asarray(decode(a)), np.asarray(x))


def test_identity_decomposition():
    """x == 16*msb4 + lsb4 with lsb4 in [0,15], msb4 in [-8,7]."""
    x = jnp.arange(-128, 128, dtype=jnp.int8)
    a = encode(x)
    lsb, msb = np.asarray(a.lsb4), np.asarray(a.msb4)
    assert lsb.min() >= 0 and lsb.max() <= 15
    assert msb.min() >= -8 and msb.max() <= 7
    np.testing.assert_array_equal(16 * msb.astype(np.int32) + lsb,
                                  np.arange(-128, 128))


def test_pbm_marks_exactly_nonzero_msb():
    x = jnp.arange(-128, 128, dtype=jnp.int8)
    a = encode(x)
    pbm = np.asarray(a.pbm)
    in_lp_range = (np.arange(-128, 128) >= LP_LOW) & \
                  (np.arange(-128, 128) <= LP_HIGH)
    np.testing.assert_array_equal(~pbm, in_lp_range)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_roundtrip_random(seed):
    x = jax.random.randint(jax.random.PRNGKey(seed), (64,), -128, 128,
                           dtype=jnp.int8)
    assert (decode(encode(x)) == x).all()


def test_sparsity_definition():
    # values 0..15 have MSB4 == 0; everything else doesn't
    x = jnp.array([0, 15, 16, -1, 7, 127, -128], dtype=jnp.int8)
    s = float(subprecision_sparsity(x))
    assert s == pytest.approx(3 / 7)


def test_eq1_compression():
    # paper: for p=8, compression% = (4s-1)/8 * 100
    for s in (0.0, 0.25, 0.5, 0.618, 1.0):
        expected = (4 * s - 1) / 8 * 100
        assert float(compression_percent(s)) == pytest.approx(expected,
                                                              abs=1e-4)


def test_eq2_ops_reduction():
    assert float(ops_reduction_percent(0.5)) == pytest.approx(25.0)
    assert float(ops_reduction_percent(0.618)) == pytest.approx(30.9)


def test_encoded_bytes_matches_eq1():
    shape = (128, 256)
    n = 128 * 256
    for s in (0.0, 0.5, 1.0):
        b = encoded_bytes(shape, s)
        dense = n  # 1 byte/elem
        saved_pct = (dense - b) / dense * 100
        assert saved_pct == pytest.approx(float(compression_percent(s)),
                                          abs=1e-3)


def test_tile_population_and_sparsity():
    pbm = jnp.zeros((8, 8), bool).at[0, 0].set(True).at[7, 7].set(True)
    pop = tile_population(pbm, 4, 4)
    np.testing.assert_array_equal(np.asarray(pop),
                                  [[1, 0], [0, 1]])
    assert float(tile_sparsity(pbm, 4, 4)) == pytest.approx(0.5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([(32, 32), (64, 128)]))
def test_tile_population_consistent_with_pbm(seed, shape):
    x = jax.random.randint(jax.random.PRNGKey(seed), shape, -128, 128,
                           dtype=jnp.int8)
    a = encode(x)
    pop = tile_population(a.pbm, 16, 16)
    assert int(pop.sum()) == int(a.pbm.sum())
