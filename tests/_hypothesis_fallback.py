"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property tests degrade gracefully: each ``@given`` test runs a fixed
number of seeded pseudo-random examples instead of hypothesis' adaptive
search. Only the tiny strategy surface this suite uses is implemented
(``integers``, ``floats``, ``sampled_from``). Install ``hypothesis``
(see requirements-dev.txt) to get real shrinking property tests.
"""
from __future__ import annotations

import random

FALLBACK_EXAMPLES = 5


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))


st = _Strategies()


def settings(**_kwargs):
    def deco(fn):
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        def run(*args, **kwargs):
            rng = random.Random(0)
            for _ in range(FALLBACK_EXAMPLES):
                drawn = [s.example(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)
        # NOT functools.wraps: pytest must see the zero-arg signature, not
        # the wrapped function's strategy parameters (no such fixtures).
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco
