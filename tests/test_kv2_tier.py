"""KV2 precision ladder: tier re-codecs, pool ladder bookkeeping, the
tiered paged kernel, and engine-level equivalence (docs/serving.md
§precision ladder, docs/format.md §KV2 tier)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to seeded fixed examples
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.serving import (Engine, PagedKVPool, PoolConfig, SamplingParams,
                           SchedulerConfig)
from repro.serving import tiering
from repro.serving.kv_pool import KV2_LOW, KV2_HIGH

CFG = ModelConfig(name="tiny-kv2", family="transformer", n_layers=2,
                  d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                  d_ff=64, vocab=128, dtype="float32")


@pytest.fixture(scope="module")
def qparams():
    fp = init_params(build_schema(CFG), jax.random.PRNGKey(0))
    return quantize_model_params(
        fp, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)


# ---------------------------------------------------------------------------
# tier re-codecs (serving/tiering.py)
# ---------------------------------------------------------------------------

def _flat_state(nib, scale, *, kv2_pages=3):
    """Minimal single-layer-group pool state holding ``nib`` (int4 values,
    shape (ps, kvh, hd)) packed into KV4 page 1, plus an empty KV2 slab."""
    from repro.core.packing import pack_plane
    ps, kvh, hd = nib.shape
    k_q = jnp.zeros((1, 2, ps, kvh, hd // 2), jnp.int8)
    k_q = k_q.at[:, 1].set(pack_plane(jnp.asarray(nib), width=4)[None])
    k_s = jnp.ones((1, 2, ps, kvh), jnp.float32).at[:, 1].set(scale)
    return {
        "k_q": k_q, "k_s": k_s,
        "v_q": k_q, "v_s": k_s,
        "k2_q": jnp.zeros((1, kv2_pages, ps, kvh, hd // 4), jnp.int8),
        "k2_s": jnp.ones((1, kv2_pages, ps, kvh), jnp.float32),
        "v2_q": jnp.zeros((1, kv2_pages, ps, kvh, hd // 4), jnp.int8),
        "v2_s": jnp.ones((1, kv2_pages, ps, kvh), jnp.float32),
    }


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([True, False]))
def test_demote_promote_roundtrip_property(seed, in_band):
    """demote -> promote is the identity on in-band pages; out-of-band
    nibbles clamp to the nearest int2 band edge with integer error at
    most 6 (dequantized: at most 6 * scale) — the documented bound."""
    from repro.core.packing import unpack_plane
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    lo, hi = (KV2_LOW, KV2_HIGH + 1) if in_band else (-8, 8)
    nib = jax.random.randint(k1, (4, 1, 8), lo, hi, dtype=jnp.int8)
    scale = jax.random.uniform(k2, (4, 1), minval=0.1, maxval=2.0)
    state = _flat_state(nib, scale)
    state = tiering.demote_page(state, jnp.int32(1), jnp.int32(2))
    # KV2 slab now holds the clamped nibbles at the untouched scale
    got2 = unpack_plane(state["k2_q"][0, 2], width=2, signed=True)
    expect = np.clip(np.asarray(nib), KV2_LOW, KV2_HIGH)
    np.testing.assert_array_equal(np.asarray(got2), expect)
    np.testing.assert_array_equal(np.asarray(state["k2_s"][0, 2]),
                                  np.asarray(scale))
    err = np.abs(np.asarray(nib, np.int32) - expect)
    assert err.max() <= 6
    if in_band:
        assert err.max() == 0
    # promote back into a fresh KV4 page: exact image of the clamp
    state = tiering.promote_page(state, jnp.int32(2), jnp.int32(0))
    got4 = unpack_plane(state["k_q"][0, 0], width=4, signed=True)
    np.testing.assert_array_equal(np.asarray(got4), expect)
    np.testing.assert_array_equal(np.asarray(state["k_s"][0, 0]),
                                  np.asarray(scale))


# ---------------------------------------------------------------------------
# pool ladder bookkeeping (serving/kv_pool.py)
# ---------------------------------------------------------------------------

def _pool(**kw):
    cfg = dict(n_pages=8, page_size=4, kv2_pages=4,
               demote_min_sparsity=0.0, demote_after_steps=1)
    cfg.update(kw)
    return PagedKVPool(CFG, PoolConfig(**cfg))


def test_pool_demote_promote_bookkeeping():
    pool = _pool()
    pool.allocate(3, owner="a")
    pool.set_demotable(["a"])
    pool.tick(); pool.tick()
    assert pool.demote_cold() == 2          # frontier page protected
    assert pool.tiers_of("a") == [1, 1, 0]
    assert pool.demotions == 2 and pool.kv2_used == 2
    assert pool.kv_bytes_saved() > 0
    assert pool.kv_bytes_reclaimed == pool.kv_bytes_saved()
    # touch promotes back (exact) and frees the KV2 pages
    pool.touch("a", 0, 1)
    assert pool.tiers_of("a") == [0, 0, 0]
    assert pool.promotions == 2 and pool.kv2_used == 0
    assert pool.kv_bytes_saved() == 0
    assert pool.tier_stats_of("a") == {"demotions": 2, "promotions": 2}


def test_pool_demote_requires_demotable_owner():
    pool = _pool()
    pool.allocate(3, owner="a")
    pool.tick(); pool.tick()
    assert pool.demote_cold() == 0          # not in the demotable set
    pool.set_demotable(["a"])
    assert pool.demote_cold() == 2
    pool.release("a")                       # release purges the set too
    pool.allocate(3, owner="a")
    pool.tick(); pool.tick()
    assert pool.demote_cold() == 0


def test_pool_release_routes_pages_to_their_tiers():
    pool = _pool()
    pool.allocate(3, owner="a")
    pool.set_demotable(["a"])
    pool.tick()
    pool.demote_cold()
    free4, free2 = pool.num_free, pool.kv2_free
    pool.release("a")
    assert pool.num_free == free4 + 1       # one KV4 page was still held
    assert pool.kv2_free == free2 + 2       # two KV2 pages returned
    assert pool.kv2_used == 0


def test_pool_demote_for_pressure_ignores_sparsity():
    pool = _pool(demote_min_sparsity=1.1)   # cold sweep can never fire
    pool.allocate(3, owner="a")
    pool.set_demotable(["a"])
    pool.tick()
    assert pool.demote_cold() == 0
    assert pool.demote_for_pressure(0, n=2) == 2
    assert pool.tiers_of("a") == [1, 1, 0]


def test_pool_disarmed_ladder_is_inert():
    pool = _pool(kv2_pages=0)
    pool.allocate(2, owner="a")
    pool.set_demotable(["a"])
    pool.tick(); pool.tick()
    assert not pool.kv2_armed
    assert pool.demote_cold() == 0 and pool.demote_for_pressure(0) == 0
    assert pool.kv_bytes_saved() == 0 and pool.kv2_used == 0


def test_pool_kv2_rejects_sharded_and_tiny_slabs():
    with pytest.raises(NotImplementedError):
        PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4, kv2_pages=4),
                    n_shards=2)
    with pytest.raises(ValueError):
        PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4, kv2_pages=1))


# ---------------------------------------------------------------------------
# tiered paged kernel (kernels/kv_attention.py)
# ---------------------------------------------------------------------------

def _paged_inputs(seed, b=2, s=256, kvh=2, g=4, hd=32, ps=64):
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(keys[0], (b, kvh, g, hd))
    n_per = s // ps
    n_pages = b * n_per + 1
    kp = jax.random.randint(keys[1], (n_pages, ps, kvh, hd // 2),
                            -128, 128, jnp.int8)
    vp = jax.random.randint(keys[2], (n_pages, ps, kvh, hd // 2),
                            -128, 128, jnp.int8)
    ksp = jax.random.uniform(keys[3], (n_pages, ps, kvh),
                             minval=0.1, maxval=1.0)
    vsp = jax.random.uniform(keys[4], (n_pages, ps, kvh),
                             minval=0.1, maxval=1.0)
    bt = jnp.arange(1, b * n_per + 1, dtype=jnp.int32).reshape(b, n_per)
    pos = jax.random.randint(keys[5], (b,), s // 2, s, jnp.int32)
    return q, kp, ksp, vp, vsp, bt, pos


def test_tiered_kernel_bitexact_on_all_kv4():
    """With every tier id 0 the tiered kernel must reproduce the KV4
    kernel bit for bit — same dequant, same flash core, same order."""
    from repro.kernels.kv_attention import (kv4_paged_decode_attention,
                                            kv_tiered_paged_decode_attention)
    q, kp, ksp, vp, vsp, bt, pos = _paged_inputs(0)
    k2 = jnp.zeros((2,) + kp.shape[1:-1] + (kp.shape[-1] // 2,), jnp.int8)
    s2 = jnp.ones((2,) + ksp.shape[1:], jnp.float32)
    ref = kv4_paged_decode_attention(q, kp, ksp, vp, vsp, bt, pos)
    out = kv_tiered_paged_decode_attention(
        q, kp, ksp, vp, vsp, k2, s2, k2, s2, bt,
        jnp.zeros_like(bt), pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_tiered_kernel_demoted_page_equals_clamped_kv4():
    """A demoted page must read back exactly as its clamp image: the
    tiered kernel over {page demoted to KV2} equals the KV4 kernel over
    {page contents clamped to the int2 band} bit for bit (the dequant
    of both slabs yields elementwise-identical f32)."""
    from repro.core.packing import pack_plane, unpack_plane
    from repro.kernels.kv_attention import (kv4_paged_decode_attention,
                                            kv_tiered_paged_decode_attention)
    q, kp, ksp, vp, vsp, bt, pos = _paged_inputs(1)
    victim = int(bt[0, 0])                  # demote batch 0's first page

    def clamp_page(qp):
        nib = unpack_plane(qp[victim], width=4, signed=True)
        return qp.at[victim].set(
            pack_plane(jnp.clip(nib, KV2_LOW, KV2_HIGH), width=4))

    ref = kv4_paged_decode_attention(
        q, clamp_page(kp), ksp, clamp_page(vp), vsp, bt, pos)

    def demote_into(qp, slab_shape):
        nib = unpack_plane(qp[victim], width=4, signed=True)
        slab = jnp.zeros(slab_shape, jnp.int8)
        return slab.at[1].set(
            pack_plane(jnp.clip(nib, KV2_LOW, KV2_HIGH), width=2))

    shape2 = (2,) + kp.shape[1:-1] + (kp.shape[-1] // 2,)
    k2, v2 = demote_into(kp, shape2), demote_into(vp, shape2)
    s2k = jnp.ones((2,) + ksp.shape[1:], jnp.float32).at[1].set(ksp[victim])
    s2v = jnp.ones((2,) + vsp.shape[1:], jnp.float32).at[1].set(vsp[victim])
    tt = jnp.zeros_like(bt).at[0, 0].set(1)
    # the demoted block-table slot points at KV2 page 1; the KV4 id is
    # dead (the engine routes via tier ids, the kernel masks to null)
    out = kv_tiered_paged_decode_attention(
        q, kp, ksp, vp, vsp, k2, s2k, v2, s2v,
        bt.at[0, 0].set(1), tt, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def _run_engine(qp, pool_cfg, gen=24):
    eng = Engine(CFG, qp, pool_config=pool_cfg,
                 sched_config=SchedulerConfig(
                     max_decode_batch=2, token_budget=32, prefill_chunk=8,
                     max_pages_per_seq=16))
    hs = [eng.submit(p, SamplingParams(max_new_tokens=gen))
          for p in ([1, 2, 3, 4, 5], [7, 8, 9])]
    eng.run()
    return eng, [h.out_tokens for h in hs], hs


@pytest.mark.slow
def test_engine_kv2_no_demotion_streams_bitexact(qparams):
    """An armed ladder that never demotes must be invisible: greedy
    streams byte-identical to the base engine (acceptance criterion)."""
    _, base, _ = _run_engine(qparams, PoolConfig(n_pages=32, page_size=4))
    eng, toks, _ = _run_engine(
        qparams, PoolConfig(n_pages=32, page_size=4, kv2_pages=8,
                            demote_after_steps=10**9))
    assert toks == base
    assert eng.pool.demotions == 0
    agg = eng.aggregate_stats()
    assert agg["pool_demotions"] == 0 and agg["kv_bytes_reclaimed"] == 0


@pytest.mark.slow
def test_engine_kv2_cold_sweep_demotes_and_accounts(qparams):
    eng, toks, hs = _run_engine(
        qparams, PoolConfig(n_pages=32, page_size=4, kv2_pages=8,
                            demote_after_steps=1, demote_min_sparsity=0.0))
    assert all(len(t) == 24 for t in toks)  # generation completed
    assert eng.pool.demotions > 0
    agg = eng.aggregate_stats()
    assert agg["pool_demotions"] == eng.pool.demotions
    assert agg["kv_bytes_reclaimed"] > 0
    assert sum(h.stats()["kv_demotions"] for h in hs) == eng.pool.demotions
    snap = eng.metrics_snapshot()
    assert "serving_pool_demotions_total" in snap
    assert "serving_pool_kv2_pages_used" in snap


@pytest.mark.slow
def test_engine_kv2_pressure_rung_prevents_eviction(qparams):
    """Under page pressure the ladder demotes before anyone is preempted:
    the tight pool that forces the base engine to evict drains without
    a single eviction when KV2 pages absorb the pressure."""
    base, _, _ = _run_engine(qparams, PoolConfig(n_pages=12, page_size=4))
    eng, toks, _ = _run_engine(
        qparams, PoolConfig(n_pages=12, page_size=4, kv2_pages=12,
                            demote_after_steps=10**9))  # pressure rung only
    assert base.pool.evictions > 0
    assert eng.pool.evictions == 0
    assert eng.pool.demotions > 0
    assert all(len(t) == 24 for t in toks)


def test_spec_engine_rejects_kv2(qparams):
    from repro.serving.spec_decode import SpecConfig, SpeculativeEngine
    with pytest.raises(NotImplementedError):
        SpeculativeEngine(CFG, qparams, spec=SpecConfig(gamma=2),
                          pool_config=PoolConfig(n_pages=32, page_size=4,
                                                 kv2_pages=8))


def test_attribute_steps_covers_tiered_decode(qparams):
    """Attribution must lower the kv2 decode step (its extra tier-table
    aval included) without error and register the decode phase."""
    eng, _, _ = _run_engine(
        qparams, PoolConfig(n_pages=32, page_size=4, kv2_pages=8,
                            demote_after_steps=10**9), gen=2)
    attr = eng.attribute_steps()
    assert "decode" in attr.phases()
