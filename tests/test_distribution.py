"""Distribution machinery: manual-EP MoE, sharding profiles, HLO analyzer,
packed KV4, dry-run lowering — the multi-device paths run in a subprocess
with forced host devices (the main test process keeps 1 device)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, shape_bytes, shape_dims


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"}
    import os
    env["PATH"] = os.environ.get("PATH", env["PATH"])
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd="/root/repo", timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


@pytest.mark.slow
def test_moe_manual_ep_matches_reference_multidevice():
    out = _run_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.sharding import mesh_context
        from repro.models import moe as moe_lib
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        t, d, e, f, k = 64, 16, 8, 24, 2
        x = jax.random.normal(key, (t, d))
        wr = jax.random.normal(jax.random.PRNGKey(1), (d, e))
        wg = jax.random.normal(jax.random.PRNGKey(2), (e, d, f)) * .3
        wu = jax.random.normal(jax.random.PRNGKey(3), (e, d, f)) * .3
        wd = jax.random.normal(jax.random.PRNGKey(4), (e, f, d)) * .3
        ref = moe_lib.moe_ffn(x, wr, wg, wu, wd, top_k=k,
                              capacity_factor=8.0)
        with mesh_context(mesh):
            y = jax.jit(lambda *a: moe_lib.moe_ffn_dist(
                *a, top_k=k, capacity_factor=8.0))(x, wr, wg, wu, wd)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-2, atol=2e-2)
        g = jax.grad(lambda w: jnp.sum(moe_lib.moe_ffn_dist(
            x, wr, w, wu, wd, top_k=k, capacity_factor=8.0) ** 2))
        with mesh_context(mesh):
            gv = jax.jit(g)(wg)
        assert bool(jnp.isfinite(gv).all())
        print("EP_OK")
    """))
    assert "EP_OK" in out


@pytest.mark.slow
def test_dryrun_lower_cell_smoke_multidevice():
    """One real lower+compile of a small cell on 64 fake devices, both
    profiles — the dry-run machinery itself under test."""
    out = _run_subprocess(textwrap.dedent("""
        import jax, json
        from repro.launch.dryrun import lower_cell
        mesh = jax.make_mesh((4, 16), ("data", "model"))
        import repro.models.registry as R
        R.ARCHS = dict(R.ARCHS)
        R.ARCHS['yi-6b'] = R.ARCHS['yi-6b'].replace(n_layers=2)
        for profile in ("baseline", "tuned"):
            rec, c = lower_cell('yi-6b', 'decode_32k', mesh,
                                profile=profile)
            assert rec['flops_hlo'] > 0
            assert rec['collective_bytes']['total'] >= 0
            print(profile, int(rec['collective_bytes']['total']))
        print("DRYRUN_OK")
    """), devices=64)
    assert "DRYRUN_OK" in out
    lines = [l for l in out.splitlines() if l.startswith(("baseline",
                                                          "tuned"))]
    base = int(lines[0].split()[1])
    tuned = int(lines[1].split()[1])
    assert tuned < base  # serving-weight replication must cut collectives


# ---------------------------------------------------------------------------
# packed KV4
# ---------------------------------------------------------------------------

def test_kv4_pack_roundtrip():
    from repro.models.model import _kv_dequant, _kv_quant
    from repro.models.registry import SMOKES
    cfg = SMOKES["granite-8b"]          # kv_bits=4
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3, 16))
    q, s = _kv_quant(cfg, x)
    assert q.shape == (2, 5, 3, 8)      # two nibbles per byte
    y = _kv_dequant(cfg, q, s, jnp.float32)
    assert y.shape == x.shape
    rel = float(jnp.abs(y - x).max() / jnp.abs(x).max())
    assert rel < 0.25                   # int4 quantization error only


def test_kv4_pack_exact_for_int_values():
    """Values already on the int4 grid roundtrip exactly through packing."""
    from repro.models.model import _kv_dequant, _kv_quant
    from repro.models.registry import SMOKES
    cfg = SMOKES["granite-8b"]
    # amax == 7 -> scale 1 -> the int grid roundtrips exactly
    ints = jnp.array([-7, -3, 0, 1, 5, 7, -1, 2],
                     dtype=jnp.float32)[None, None, None, :]
    q, s = _kv_quant(cfg, ints)
    y = _kv_dequant(cfg, q, s, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ints), atol=1e-5)


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_shape_bytes():
    assert shape_bytes("bf16[4,8]{1,0}") == 64
    assert shape_bytes("(f32[2]{0}, s8[3]{0})") == 11
    assert shape_dims("s32[128,16]{1,0}") == [("s32", [128, 16])]


HLO = """\
HloModule test

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %a1 = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[4,4]{1,0} dot(%a1, %a1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,4]{1,0} all-reduce(%dot.1), to_apply=%add
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %c = s32[] constant(5)
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %b2 = f32[4,8]{1,0} parameter(1)
  %w = (s32[], f32[4,4]{1,0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %dot.2 = f32[4,8]{1,0} dot(%a, %b2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_analyzer_trip_count_multiplication():
    st = analyze(HLO)
    # dot.1 (2*4*4*4=128 flops) x5 trips + dot.2 (2*4*8*4=256) x1
    assert st.flops == 128 * 5 + 256
    assert st.coll_bytes["all-reduce"] == 64 * 5
    assert st.coll_count["all-reduce"] == 5


def test_analyzer_parses_real_artifact():
    """The committed dry-run artifacts were produced by this analyzer;
    cross-check one for internal consistency."""
    import os
    path = "runs/dryrun/singlepod/yi-6b__train_4k.json"
    if not os.path.exists(path):
        pytest.skip("dry-run artifacts not present")
    rec = json.load(open(path))
    assert rec["flops_hlo"] > 1e13                     # scan-multiplied
    assert rec["collective_bytes"]["total"] == pytest.approx(
        sum(v for k, v in rec["collective_bytes"].items()
            if k != "total"))
    # 6ND useful-flops sanity: within [0.2, 1.0] of compiled flops
    from benchmarks.roofline import model_flops
    mf = model_flops("yi-6b", "train_4k") / rec["n_devices"]
    assert 0.2 < mf / rec["flops_hlo"] < 1.0


# ---------------------------------------------------------------------------
# profiles
# ---------------------------------------------------------------------------

def test_profile_rules_decisions():
    from repro.distributed.sharding import profile_rules
    from repro.models.registry import ARCHS

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    # small dense model: tuned drops FSDP for train and serve
    assert profile_rules("tuned", ARCHS["yi-6b"], "train", fm) == \
        {"embed": ()}
    assert profile_rules("tuned", ARCHS["granite-8b"], "decode", fm,
                         global_batch=128) == {"embed": ()}
    # 671B: keeps FSDP
    assert profile_rules("tuned", ARCHS["deepseek-v3-671b"], "train",
                         fm) == {}
    # degenerate decode batch keeps FSDP
    assert profile_rules("tuned", ARCHS["gemma3-27b"], "decode", fm,
                         global_batch=1) == {}
    # baseline never overrides
    assert profile_rules("baseline", ARCHS["yi-6b"], "train", fm) == {}
