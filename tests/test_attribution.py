"""Attribution + SLO subsystem: HLO coverage, drift math, watchdog.

Covers the PR-9 acceptance points: ``launch/hlo_analysis`` strict mode
against the REAL compiled serving steps (single-device and 2x2 mesh,
CPU backend), deterministic sliding-window percentiles, drift-metric
math on a synthetic clock via the ``register_cost`` seam, and the SLO
watchdog firing (test-pinned) on an injected latency spike while
staying silent on the baseline run.
"""
import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.launch import hlo_analysis as H
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.obs import Observability
from repro.obs.attribution import StepAttribution, StepCost
from repro.obs.slo import (SLO, SLOMonitor, SlidingWindow, parse_slo,
                           parse_slo_list)
from repro.obs.validate import validate_attribution
from repro.serving import (Engine, PoolConfig, SamplingParams,
                           SchedulerConfig, SpecConfig, SpeculativeEngine)

CFG = ModelConfig(name="tiny-attr", family="transformer", n_layers=2,
                  d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                  d_ff=64, vocab=128, dtype="float32")


class FakeClock:
    """Deterministic monotonic clock: every read advances by ``dt``."""

    def __init__(self, dt=0.001):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _qparams(cfg, seed=0):
    fp = init_params(build_schema(cfg), jax.random.PRNGKey(seed))
    return quantize_model_params(
        fp, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)


def _engine(cfg=CFG, mesh=None, gamma=0, slos=None, clock=None):
    kw = dict(pool_config=PoolConfig(n_pages=32, page_size=4),
              sched_config=SchedulerConfig(max_decode_batch=4,
                                           token_budget=64,
                                           prefill_chunk=8,
                                           max_pages_per_seq=8),
              mesh=mesh, slos=slos)
    if clock is not None:
        kw["clock"] = clock
    qp = _qparams(cfg)
    if gamma:
        return SpeculativeEngine(cfg, qp, spec=SpecConfig(gamma=gamma),
                                 **kw)
    return Engine(cfg, qp, **kw)


# ---------------------------------------------------------------------------
# hlo_analysis: sub-byte dtypes + strict coverage
# ---------------------------------------------------------------------------

def test_s4_dtype_bytes_are_fractional():
    assert H.shape_bytes("s4[16]{0}") == 8.0
    assert H.shape_bytes("u4[3]") == 1.5
    assert H.shape_bytes("s2[8]") == 2.0
    assert H.shape_bytes("pred[10]") == 10.0


def test_unknown_dtype_fails_strict():
    text = """HloModule m
ENTRY %main (p: myfancytype[8]) -> myfancytype[8] {
  %p = myfancytype[8]{0} parameter(0)
  ROOT %r = myfancytype[8]{0} copy(%p)
}
"""
    with pytest.raises(H.HloCoverageError, match="unknown dtype"):
        H.analyze(text, strict=True)
    stats = H.analyze(text)                  # non-strict still records
    assert stats.unknown_dtypes
    assert not stats.complete


def test_unparsed_op_fails_strict():
    text = """HloModule m
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %oops = utterly unparseable line
  ROOT %r = f32[4]{0} copy(%p)
}
"""
    with pytest.raises(H.HloCoverageError, match="unparsed"):
        H.analyze(text, strict=True)
    stats = H.analyze(text)
    assert any("oops" in s for s in stats.unparsed_ops)


def test_no_entry_fails_strict():
    with pytest.raises(H.HloCoverageError, match="ENTRY"):
        H.analyze("HloModule empty\n", strict=True)


# ---------------------------------------------------------------------------
# attribution against the real compiled serving steps
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_attribute_real_steps_single_device():
    eng = _engine()
    attr = eng.attribute_steps()
    assert set(attr.phases()) == {"prefill", "decode"}
    for phase in attr.phases():
        c = attr.cost(phase)
        # strict analyze() inside attribute() already guarantees full
        # coverage; the numbers must be real work, not zeros
        assert c.flops > 0 and c.hbm_bytes > 0
        assert c.compile_seconds > 0
    # one prefill chunk moves `prefill_chunk` tokens, one decode step
    # moves one token per slot
    assert attr.cost("prefill").tokens_per_step == 8
    assert attr.cost("decode").tokens_per_step == 4
    # idempotent: re-attribution returns the cached costs
    again = eng.attribute_steps()
    assert again is attr
    assert again.cost("decode") is attr.cost("decode")
    # gauges registered and set
    r = eng.obs.registry
    assert r.value("serving_step_attr_flops", phase="decode") > 0
    problems = validate_attribution(r.snapshot(), require=True)
    assert problems == []


@pytest.mark.slow
def test_attribute_real_steps_spec_engine():
    eng = _engine(gamma=2)
    attr = eng.attribute_steps()
    assert set(attr.phases()) == {"prefill", "decode", "draft", "verify"}
    draft, verify = attr.cost("draft"), attr.cost("verify")
    # the timed draft phase wraps gamma jitted calls
    assert draft.calls_per_step == 2
    assert draft.tokens_per_step == 4 * 2
    assert verify.tokens_per_step == 4 * 3
    # the LSB4-only draft program does strictly less dot work per call
    # than gamma-scaled full decode would
    assert draft.flops < 2 * attr.cost("decode").flops


@pytest.mark.slow
def test_attribute_real_steps_mesh(mesh):
    m = mesh(data=2, model=2)
    eng = _engine(mesh=m)
    attr = eng.attribute_steps()
    assert set(attr.phases()) == {"prefill", "decode"}
    for phase in attr.phases():
        c = attr.cost(phase)
        assert c.flops > 0 and c.hbm_bytes > 0
        # tensor parallelism must show up as collective payload
        assert c.coll_bytes.get("total", 0.0) > 0
    problems = validate_attribution(eng.obs.registry.snapshot(),
                                    require=True)
    assert problems == []


@pytest.mark.slow
def test_runtime_join_after_real_run():
    eng = _engine(clock=FakeClock(dt=0.001))
    eng.attribute_steps()
    for i in range(3):
        eng.submit([1, 2, 3, 4 + i], SamplingParams(max_new_tokens=3))
    eng.run()
    snap = eng.metrics_snapshot()
    r = eng.obs.registry
    for phase in ("prefill", "decode"):
        assert r.value("serving_roofline_compute_util_ratio",
                       phase=phase) > 0
        assert r.value("serving_costmodel_latency_drift_ratio",
                       phase=phase) > 0
    wire = r.value("serving_costmodel_wire_drift_ratio")
    # Eq.1 tracks the measured codec to a couple percent (PR 3)
    assert abs(wire - 1.0) < 0.05
    assert validate_attribution(snap, require=True) == []


# ---------------------------------------------------------------------------
# drift math on a synthetic clock (register_cost seam)
# ---------------------------------------------------------------------------

def _seamed_attr():
    obs = Observability(clock=FakeClock())
    attr = StepAttribution(obs)
    attr.register_cost(
        StepCost(phase="decode", flops=1e9, hbm_bytes=2e9,
                 coll_bytes={"total": 0.0}, tokens_per_step=8),
        predict_seconds=lambda s: 0.010)       # constant 10 ms predicted
    return obs, attr


def test_roofline_join_math():
    obs, attr = _seamed_attr()
    attr.observe_runtime("decode", 0.020)      # 20 ms measured
    r = obs.registry
    assert r.value("serving_roofline_achieved_flops_per_s",
                   phase="decode") == pytest.approx(1e9 / 0.020)
    assert r.value("serving_roofline_compute_util_ratio",
                   phase="decode") == pytest.approx(
                       1e9 / 0.020 / attr.hw.peak_flops)
    assert r.value("serving_roofline_memory_util_ratio",
                   phase="decode") == pytest.approx(
                       2e9 / 0.020 / attr.hw.hbm_bw)
    assert r.value("serving_costmodel_latency_drift_ratio",
                   phase="decode") == pytest.approx(2.0)


def test_latency_drift_is_edge_triggered_vs_reference():
    obs, attr = _seamed_attr()
    r = obs.registry
    attr.observe_runtime("decode", 0.020)      # pins reference ratio 2.0
    attr.observe_runtime("decode", 0.030)      # ratio 3.0, within 2x band
    assert r.value("serving_costmodel_drift_events_total",
                   phase="decode") == 0
    attr.observe_runtime("decode", 0.050)      # ratio 5.0 > 2*ref: fires
    assert r.value("serving_costmodel_drift_events_total",
                   phase="decode") == 1
    attr.observe_runtime("decode", 0.060)      # still out: no re-fire
    assert r.value("serving_costmodel_drift_events_total",
                   phase="decode") == 1
    attr.observe_runtime("decode", 0.020)      # recovery re-arms
    attr.observe_runtime("decode", 0.002)      # ratio 0.2 < ref/2: fires
    assert r.value("serving_costmodel_drift_events_total",
                   phase="decode") == 2
    instants = [e for e in obs.tracer._events
                if e["name"] == "costmodel_drift"]
    assert len(instants) == 2
    assert all(e["args"]["kind"] == "latency" for e in instants)


def test_wire_drift_edge_triggered():
    obs, attr = _seamed_attr()
    r = obs.registry
    attr.observe_wire(100.0, 100.5)            # ratio ~0.995: in band
    assert r.value("serving_costmodel_drift_events_total",
                   phase="wire") == 0
    attr.observe_wire(130.0, 100.0)            # ratio 1.3 > 1.15: fires
    assert r.value("serving_costmodel_drift_events_total",
                   phase="wire") == 1
    attr.observe_wire(135.0, 100.0)            # sustained: no re-fire
    assert r.value("serving_costmodel_drift_events_total",
                   phase="wire") == 1
    assert r.value("serving_costmodel_wire_drift_ratio") == \
        pytest.approx(1.35)


# ---------------------------------------------------------------------------
# sliding window percentiles: deterministic nearest-rank
# ---------------------------------------------------------------------------

def test_sliding_window_nearest_rank():
    w = SlidingWindow(maxlen=100)
    for v in [5.0, 1.0, 3.0, 2.0, 4.0]:
        w.observe(v)
    assert w.percentile(50) == 3.0             # ceil(0.5*5)=3rd of sorted
    assert w.percentile(95) == 5.0
    assert w.percentile(20) == 1.0
    assert w.percentile(100) == 5.0
    assert w.over_fraction(3.0) == pytest.approx(2 / 5)


def test_sliding_window_evicts_oldest():
    w = SlidingWindow(maxlen=3)
    for v in [10.0, 20.0, 30.0, 40.0]:
        w.observe(v)
    assert len(w) == 3 and w.total == 4
    assert w.percentile(50) == 30.0            # 10.0 evicted
    with pytest.raises(ValueError):
        w.observe(float("nan"))


def test_parse_slo_specs():
    slo = parse_slo("ttft:p95<0.25")
    assert (slo.signal, slo.percentile, slo.target) == ("ttft", 95.0, 0.25)
    assert slo.unit == "seconds"
    assert parse_slo("queue_depth:p50<4").unit == "requests"
    assert len(parse_slo_list("ttft:p95<1,tpot:p99<0.5")) == 2
    assert parse_slo_list("") == []
    with pytest.raises(ValueError):
        parse_slo("nonsense")
    with pytest.raises(ValueError):
        parse_slo("latency:p95<1")             # unknown signal
    with pytest.raises(ValueError):
        SLO(name="bad", signal="ttft", target=1.0, percentile=0.0)


# ---------------------------------------------------------------------------
# SLO watchdog: fires on an injected spike, silent on the baseline
# ---------------------------------------------------------------------------

def test_slo_violation_fires_on_spike_and_rearms():
    obs = Observability(clock=FakeClock())
    mon = SLOMonitor([SLO(name="tpot", signal="tpot", target=0.1,
                          percentile=95.0, window=8)], obs)
    r = obs.registry
    for _ in range(8):
        mon.observe("tpot", 0.01)              # healthy baseline
    assert r.value("serving_slo_compliant", slo="tpot") == 1.0
    assert r.value("serving_slo_violations_total", slo="tpot") == 0
    # injected latency spike: window p95 jumps over target
    for _ in range(8):
        mon.observe("tpot", 0.5)
    assert r.value("serving_slo_compliant", slo="tpot") == 0.0
    assert r.value("serving_slo_violations_total", slo="tpot") == 1.0
    assert r.value("serving_slo_burn_rate", slo="tpot") > 1.0
    instants = [e for e in obs.tracer._events
                if e["name"] == "slo_violation"]
    assert len(instants) == 1                  # edge-triggered, not 8
    assert instants[0]["args"]["slo"] == "tpot"
    # recovery drains the spike out of the window and re-arms the edge
    for _ in range(8):
        mon.observe("tpot", 0.01)
    assert r.value("serving_slo_compliant", slo="tpot") == 1.0
    for _ in range(8):
        mon.observe("tpot", 0.5)
    assert r.value("serving_slo_violations_total", slo="tpot") == 2.0


def test_slo_min_samples_gates_judgement():
    obs = Observability(clock=FakeClock())
    mon = SLOMonitor([SLO(name="q", signal="queue_depth", target=1.0,
                          window=16, min_samples=4)], obs)
    for _ in range(3):
        mon.observe("queue_depth", 50.0)       # over target but unjudged
    assert obs.registry.value("serving_slo_compliant", slo="q") == 1.0
    mon.observe("queue_depth", 50.0)           # 4th sample: judged
    assert obs.registry.value("serving_slo_compliant", slo="q") == 0.0
    rep = mon.report()[0]
    assert rep["violating"] and rep["violations"] == 1


@pytest.mark.slow
def test_engine_slos_silent_on_baseline_run():
    # generous targets on a fast synthetic run: the watchdog must stay
    # quiet end-to-end (the CI fast lane runs the same shape via
    # `bench_serving --slo ... --slo-fail`)
    slos = parse_slo_list("ttft:p95<60,tpot:p95<60,queue_depth:p50<64")
    eng = _engine(slos=slos, clock=FakeClock(dt=0.001))
    for i in range(3):
        eng.submit([1, 2, 3, 4 + i], SamplingParams(max_new_tokens=3))
    eng.run()
    assert eng.slo is not None
    assert all(v == 0 for v in eng.slo.violations().values())
    assert all(not rep["violating"] for rep in eng.slo.report())
    # every signal actually produced samples
    assert all(rep["samples"] > 0 for rep in eng.slo.report())


@pytest.mark.slow
def test_engine_slo_fires_on_tight_target():
    # a FakeClock tick is 1 ms, and every _emit reads the clock, so any
    # sub-millisecond TPOT target must violate deterministically
    slos = [SLO(name="tight", signal="tpot", target=1e-6, window=8)]
    eng = _engine(slos=slos, clock=FakeClock(dt=0.001))
    eng.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
    eng.run()
    assert eng.slo.violations()["tight"] >= 1
    names = [e["name"] for e in eng.obs.tracer._events]
    assert "slo_violation" in names
