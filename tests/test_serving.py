"""Serving subsystem: pool invariants, paged-kernel exactness, and
engine-vs-legacy token equivalence under continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.models import model as M
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.serving import (Engine, PagedKVPool, PoolConfig, SamplingParams,
                           Scheduler, SchedulerConfig)

# float32 compute so the engine's f32 attention paths and the legacy bf16-
# free path agree to fp rounding — greedy tokens then match exactly.
CFG = ModelConfig(name="tiny-serve", family="transformer", n_layers=2,
                  d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                  d_ff=64, vocab=128, dtype="float32")


@pytest.fixture(scope="module")
def fparams():
    return init_params(build_schema(CFG), jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qparams(fparams):
    return quantize_model_params(
        fparams, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)


def _legacy_greedy(qp, prompt, gen):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    plen = toks.shape[1]
    logits, cache = M.prefill(CFG, qp, {"tokens": toks}, max_len=plen + gen)
    out = [int(jnp.argmax(logits, -1)[0])]
    for i in range(gen - 1):
        pos = jnp.full((1,), plen + i, jnp.int32)
        lg, cache = M.decode_step(CFG, qp, cache,
                                  jnp.asarray([out[-1]], jnp.int32), pos)
        out.append(int(jnp.argmax(lg, -1)[0]))
    return out


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------

def test_pool_alloc_free_invariants():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    assert pool.n_usable_pages == 7            # page 0 reserved (null page)
    a = pool.allocate(3, owner="a")
    b = pool.allocate(4, owner="b")
    assert 0 not in a + b                      # null page never handed out
    assert len(set(a + b)) == 7                # no page handed out twice
    assert pool.allocate(1, owner="c") is None  # exhausted: no partial grab
    assert pool.num_free == 0
    freed = pool.release("a")
    assert sorted(freed) == sorted(a)
    assert pool.num_free == 3
    c = pool.allocate(3, owner="c")
    assert sorted(c) == sorted(a)              # recycled
    assert pool.release("missing") == []       # idempotent


def test_pool_eviction_hook_fires():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    pages = pool.allocate(2, owner=7)
    seen = []
    pool.on_evict = lambda owner, pgs: seen.append((owner, list(pgs)))
    evicted = pool.evict(7)
    assert evicted == pages and seen == [(7, pages)]
    assert pool.evictions == 1 and pool.num_free == 7


def test_pool_evict_unknown_owner_is_noop():
    """Evicting an owner that holds no pages must not bump the eviction
    counter or fire the hook (scheduler churn can retry a preemption
    after the victim already released)."""
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    fired = []
    pool.on_evict = lambda owner, pgs: fired.append(owner)
    assert pool.evict("never-allocated") == []
    assert pool.evictions == 0 and fired == []
    # a real eviction still counts
    pool.allocate(2, owner="a")
    pool.evict("a")
    assert pool.evictions == 1 and fired == ["a"]
    # ... and evicting the same owner again is a no-op
    assert pool.evict("a") == []
    assert pool.evictions == 1 and fired == ["a"]


def test_pool_zero_page_allocate_no_phantom_owner():
    """allocate(0, owner) must not create an ownership entry: release()
    and evict() treat map presence as 'holds pages', so a phantom entry
    drifts the ownership map under scheduler churn."""
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    assert pool.allocate(0, owner="ghost") == []
    assert "ghost" not in pool._owned
    assert pool.pages_of("ghost") == []
    assert pool.evict("ghost") == [] and pool.evictions == 0
    # zero-grab on an EXISTING owner leaves its pages untouched
    pages = pool.allocate(2, owner="real")
    assert pool.allocate(0, owner="real") == []
    assert pool.pages_of("real") == pages


def test_pool_msb_sparsity_all_16_nibble_values():
    """Regression for the signed-nibble criterion: sub-precision nibbles
    are exactly those in [KV2_LOW, KV2_HIGH] = [-2, 1] (signed int2
    range). The old arithmetic-shift test (nib >> 2 == 0) wrongly
    excluded -2 and -1 (and counted 2 and 3, which need 3 signed bits)."""
    from repro.serving.kv_pool import KV2_LOW, KV2_HIGH
    assert (KV2_LOW, KV2_HIGH) == (-2, 1)
    for v in range(-8, 8):
        pool = PagedKVPool(CFG, PoolConfig(n_pages=4, page_size=4))
        byte = np.uint8((v & 0xF) | ((v & 0xF) << 4)).astype(np.int8)
        pool.state = jax.tree_util.tree_map(
            lambda a: (a.at[:, 1].set(byte) if a.dtype == jnp.int8 else a),
            pool.state)
        s = pool.page_msb_sparsity([1])
        expected = 1.0 if KV2_LOW <= v <= KV2_HIGH else 0.0
        np.testing.assert_allclose(s, [expected], err_msg=f"nibble {v}")


def test_pool_msb_sparsity_mixed_nibbles_fraction():
    """A page holding every int4 value equally often reports 4/16."""
    pool = PagedKVPool(CFG, PoolConfig(n_pages=4, page_size=4))
    nibbles = np.arange(-8, 8, dtype=np.int8)          # all 16 values
    seq = np.tile(nibbles, 4)                          # 64 nibbles/page leaf
    packed = ((seq[0::2] & 0xF) | ((seq[1::2] & 0xF) << 4)).astype(np.int8)
    page = jnp.asarray(packed.reshape(4, 2, 4))        # (ps, kvh, hd/2)
    pool.state = jax.tree_util.tree_map(
        lambda a: (a.at[:, 1].set(page) if a.dtype == jnp.int8 else a),
        pool.state)
    np.testing.assert_allclose(pool.page_msb_sparsity([1]), [4 / 16])


def test_pool_msb_sparsity_telemetry():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=4, page_size=4))
    # zero-initialized nibbles are all sub-precision (value 0)
    s = pool.page_msb_sparsity([1, 2])
    np.testing.assert_allclose(s, [1.0, 1.0])
    # 0x77 -> both nibbles 7: high two bits nonzero everywhere on page 2
    full = jax.tree_util.tree_map(
        lambda a: (a.at[:, 2].set(0x77) if a.dtype == jnp.int8 else a),
        pool.state)
    pool.state = full
    s = pool.page_msb_sparsity([1, 2])
    np.testing.assert_allclose(s, [1.0, 0.0])


# ---------------------------------------------------------------------------
# paged kernel vs contiguous kernel
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("b,s,kvh,g,hd,ps", [
    (2, 256, 2, 4, 32, 64), (1, 256, 1, 2, 16, 128),
])
def test_paged_kernel_bitexact_vs_contiguous(b, s, kvh, g, hd, ps):
    """Walking a (shuffled) block table must reproduce the contiguous
    kernel bit for bit — same body, same block shapes, same order."""
    from repro.kernels.kv_attention import (kv4_decode_attention,
                                            kv4_paged_decode_attention)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, kvh, g, hd))
    kq = jax.random.randint(jax.random.PRNGKey(1), (b, s, kvh, hd // 2),
                            -128, 128, jnp.int8)
    vq = jax.random.randint(jax.random.PRNGKey(2), (b, s, kvh, hd // 2),
                            -128, 128, jnp.int8)
    ks = jax.random.uniform(jax.random.PRNGKey(3), (b, s, kvh),
                            minval=0.1, maxval=1.0)
    vs = jax.random.uniform(jax.random.PRNGKey(4), (b, s, kvh),
                            minval=0.1, maxval=1.0)
    pos = jax.random.randint(jax.random.PRNGKey(5), (b,), 1, s, jnp.int32)
    ref = kv4_decode_attention(q, kq, ks, vq, vs, pos, bs=ps)

    n_per = s // ps
    n_pages = b * n_per + 1
    perm = np.random.RandomState(0).permutation(b * n_per) + 1
    kp = np.zeros((n_pages, ps, kvh, hd // 2), np.int8)
    vp = np.zeros_like(kp)
    ksp = np.zeros((n_pages, ps, kvh), np.float32)
    vsp = np.zeros_like(ksp)
    bt = np.zeros((b, n_per), np.int32)
    for i in range(b):
        for j in range(n_per):
            pid = int(perm[i * n_per + j])
            bt[i, j] = pid
            sl = slice(j * ps, (j + 1) * ps)
            kp[pid], vp[pid] = kq[i, sl], vq[i, sl]
            ksp[pid], vsp[pid] = ks[i, sl], vs[i, sl]
    out = kv4_paged_decode_attention(
        q, jnp.asarray(kp), jnp.asarray(ksp), jnp.asarray(vp),
        jnp.asarray(vsp), jnp.asarray(bt), pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# engine vs legacy
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_matches_legacy_8_staggered_requests(qparams):
    """8 staggered requests of different lengths through the continuous-
    batching engine produce the same greedy tokens as the legacy
    fixed-batch path (whole-prompt prefill chunks; 4 decode slots force
    backfill)."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, CFG.vocab, size=n).tolist()
               for n in (12, 20, 5, 30, 9, 17, 26, 14)]
    gen = 6
    eng = Engine(CFG, qparams,
                 pool_config=PoolConfig(n_pages=64, page_size=8),
                 sched_config=SchedulerConfig(
                     max_decode_batch=4, token_budget=256,
                     prefill_chunk=32, max_pages_per_seq=8))
    handles = []
    for p in prompts:                      # staggered: step between submits
        handles.append(eng.submit(p, SamplingParams(max_new_tokens=gen)))
        eng.step()
    eng.run()
    for h, p in zip(handles, prompts):
        assert h.done and h.n_generated == gen
        assert h.out_tokens == _legacy_greedy(qparams, p, gen), h.rid
        st = h.stats()
        assert np.isfinite(st["ttft_s"]) and np.isfinite(st["tpot_s"])
        assert 0.0 <= st["act_sparsity"] <= 1.0
        # measured wire-format accounting rides along per request
        assert np.isfinite(st["act_wire_bytes_per_token"])
        assert st["act_wire_bytes_per_token"] > 0
        assert np.isfinite(st["act_wire_compression_pct"])
    # backfilled slots: 8 requests through 4 slots, everything released
    assert eng.pool.num_free == eng.pool.n_usable_pages
    # ... and per-layer in aggregate: one entry per transformer layer
    agg = eng.aggregate_stats()
    assert len(agg["layer_wire_bytes_per_token"]) == CFG.n_layers
    assert all(b > 0 for b in agg["layer_wire_bytes_per_token"])
    # dense baseline per layer-input row is d_model bytes
    assert all(abs(d - CFG.d_model) < 1e-6
               for d in agg["layer_dense_bytes_per_token"])
    assert agg["wire_bytes_total"] > 0


@pytest.mark.slow
def test_engine_packed_wire_format_matches_unpacked(fparams, qparams):
    """Serving with wire_format='packed' (activations round-trip the
    packed codec before every projection) produces the same greedy tokens
    as the unpacked path — the codec is exact, so the format change is
    invisible to the math."""
    qp_packed = quantize_model_params(
        fparams, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16,
        wire_format="packed")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, CFG.vocab, size=n).tolist() for n in (11, 18)]
    outs = []
    for qp in (qparams, qp_packed):
        eng = Engine(CFG, qp,
                     pool_config=PoolConfig(n_pages=16, page_size=8),
                     sched_config=SchedulerConfig(
                         max_decode_batch=2, token_budget=64,
                         prefill_chunk=32, max_pages_per_seq=8))
        hs = [eng.submit(p, SamplingParams(max_new_tokens=5))
              for p in prompts]
        eng.run()
        outs.append([h.out_tokens for h in hs])
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_engine_chunked_prefill_completes(qparams):
    """A prompt longer than the chunk is prefilled across several steps
    (interleaving with decodes) and still completes."""
    rng = np.random.RandomState(3)
    long_p = rng.randint(0, CFG.vocab, size=40).tolist()
    short_p = rng.randint(0, CFG.vocab, size=6).tolist()
    eng = Engine(CFG, qparams,
                 pool_config=PoolConfig(n_pages=32, page_size=8),
                 sched_config=SchedulerConfig(
                     max_decode_batch=2, token_budget=16,
                     prefill_chunk=16, max_pages_per_seq=8))
    h1 = eng.submit(long_p, SamplingParams(max_new_tokens=4))
    h2 = eng.submit(short_p, SamplingParams(max_new_tokens=4))
    eng.run()
    assert h1.n_generated == 4 and h2.n_generated == 4
    # 40-token prompt at chunk 16 needs >= 3 prefill steps
    assert eng.steps >= 3


@pytest.mark.slow
def test_prefill_chunk_boundary_mask_oracle(qparams):
    """The past/chunk attention boundary of _attn_prefill_chunk_paged,
    checked against an independent naive reference with *exactly
    representable* past K/V (int4 values, unit scales) so quantization
    contributes zero error. start=6 with page_size=4 puts the boundary
    mid-page — the off-by-one hot spot."""
    from repro.core.qlinear import linear
    from repro.models.stages import build_stages
    p0 = jax.tree_util.tree_map(lambda a: a[0], qparams["stages"]["s0"]["p0"])
    ld = build_stages(CFG)[0].period[0]
    kvh, H, hd, d = CFG.n_kv_heads, CFG.n_heads, CFG.hd, CFG.d_model
    ps, n_pages = 4, 4
    start, c = 6, 5
    rs = np.random.RandomState(0)
    past_int = rs.randint(-8, 8, size=(n_pages, ps, kvh, hd)).astype(np.int8)

    def pack(v):
        return jnp.asarray(((v[..., 0::2] & 0xF) |
                            ((v[..., 1::2] & 0xF) << 4)).astype(np.int8))

    pool = {"k_q": pack(past_int), "k_s": jnp.ones((n_pages, ps, kvh)),
            "v_q": pack(past_int[::-1]),
            "v_s": jnp.ones((n_pages, ps, kvh))}
    bt = jnp.arange(n_pages, dtype=jnp.int32)[None]
    x = jnp.asarray(rs.randn(1, c, d), jnp.float32)
    out, _ = M._attn_prefill_chunk_paged(
        CFG, ld, p0, x, pool, bt, jnp.asarray(start, jnp.int32),
        jnp.asarray(c, jnp.int32))

    h = M._norm(CFG, p0["ln"], x)
    q, k, v = M._attn_qkv(CFG, p0, h, start + jnp.arange(c), CFG.rope_theta)
    past_k = jnp.asarray(past_int.reshape(-1, kvh, hd), jnp.float32)
    past_v = jnp.asarray(past_int[::-1].reshape(-1, kvh, hd), jnp.float32)
    k_ctx = jnp.concatenate([past_k[:start], k[0].astype(jnp.float32)], 0)
    v_ctx = jnp.concatenate([past_v[:start], v[0].astype(jnp.float32)], 0)
    qg = q[0].reshape(c, kvh, H // kvh, hd).astype(jnp.float32)
    s = jnp.einsum("ikgd,jkd->kgij", qg, k_ctx) * hd ** -0.5
    allow = (jnp.arange(start + c)[None, :] <=
             start + jnp.arange(c)[:, None])
    s = jnp.where(allow[None, None], s, M.NEG_INF)
    o = jnp.einsum("kgij,jkd->ikgd", jax.nn.softmax(s, -1), v_ctx)
    ref = linear(o.reshape(1, c, H * hd).astype(x.dtype), p0["wo"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_chunked_prefill_pool_writes_chunk_invariant(qparams):
    """Quantize-on-write must not depend on chunking: after prefilling the
    same prompt in 1, 2, or 5 chunks, the first layer's page contents are
    bit-identical (layer-0 K/V depend only on embeddings) and the final
    logits drift only by the quantized-past perturbation, not O(1)."""
    from repro.serving.kv_pool import PagedKVPool, PoolConfig
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, CFG.vocab, size=36)

    def run(chunks):
        pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=8))
        pages = pool.allocate(5, "r")
        bt = np.zeros((1, 8), np.int32)
        bt[0, :5] = pages
        state, start = pool.state, 0
        for c in chunks:
            toks = jnp.asarray(prompt[start:start + c], jnp.int32)[None]
            lg, state, _ = M.prefill_chunk_paged(
                CFG, qparams, state, toks, jnp.asarray(start, jnp.int32),
                jnp.asarray(c, jnp.int32), jnp.asarray(bt))
            start += c
        return np.asarray(lg[0]), state, pages

    lg1, st1, pg1 = run([36])
    i1 = np.asarray(pg1)
    for chunks in ([24, 12], [8, 8, 8, 8, 4]):
        lgN, stN, pgN = run(chunks)
        iN = np.asarray(pgN)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a[0][i1]), np.asarray(b[0][iN])), st1, stN)
        assert np.abs(lg1 - lgN).max() < 0.05, chunks


@pytest.mark.slow
def test_engine_preemption_under_page_pressure(qparams):
    """A pool too small for the working set preempts (evicts + recomputes)
    rather than deadlocking, and every request still finishes."""
    rng = np.random.RandomState(1)
    eng = Engine(CFG, qparams,
                 pool_config=PoolConfig(n_pages=10, page_size=4),
                 sched_config=SchedulerConfig(
                     max_decode_batch=4, token_budget=64,
                     prefill_chunk=16, max_pages_per_seq=8))
    hs = [eng.submit(rng.randint(0, CFG.vocab, size=14).tolist(),
                     SamplingParams(max_new_tokens=10)) for _ in range(4)]
    eng.run()
    assert all(h.n_generated == 10 for h in hs)
    assert eng.pool.evictions > 0
    assert sum(h.stats()["preemptions"] for h in hs) > 0


@pytest.mark.slow
def test_engine_stream_and_temperature(qparams):
    """stream() yields tokens as they are produced; temperature sampling
    is seeded and in-vocab."""
    rng = np.random.RandomState(2)
    eng = Engine(CFG, qparams,
                 pool_config=PoolConfig(n_pages=16, page_size=8),
                 sched_config=SchedulerConfig(max_decode_batch=2,
                                              token_budget=64,
                                              prefill_chunk=16,
                                              max_pages_per_seq=4))
    h = eng.submit(rng.randint(0, CFG.vocab, size=10).tolist(),
                   SamplingParams(max_new_tokens=5, temperature=0.8, seed=3))
    got = list(eng.stream(h))
    assert got == h.out_tokens and len(got) == 5
    assert all(0 <= t < CFG.vocab for t in got)


def test_decode_paged_telemetry_covers_every_sublayer():
    """Per-layer telemetry must have one entry per LAYER, not per scanned
    period: a GQA MoE config with moe_every=2 (period length 2) passes
    check_paged_support and must still report n_layers wire-byte rows."""
    cfg = ModelConfig(name="tiny-moe-serve", family="moe", n_layers=4,
                      d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_ff=64, vocab=64, dtype="float32", n_experts=4,
                      top_k=2, moe_every=2, moe_d_ff=32,
                      router_type="softmax")
    M.check_paged_support(cfg)
    from repro.serving.kv_pool import PagedKVPool, PoolConfig
    params = init_params(build_schema(cfg), jax.random.PRNGKey(1))
    pool = PagedKVPool(cfg, PoolConfig(n_pages=4, page_size=4))
    token = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    tables = jnp.zeros((2, 2), jnp.int32)
    _, _, tel = M.decode_step_paged(cfg, params, pool.state, token, pos,
                                    tables)
    assert tel["layer_wire_bytes"].shape == (cfg.n_layers, 2)
    assert tel["layer_sparsity"].shape == (cfg.n_layers, 2)
    np.testing.assert_allclose(np.asarray(tel["layer_dense_bytes"]),
                               np.full((cfg.n_layers, 2), cfg.d_model))


def test_scheduler_token_budget_and_fcfs():
    """Pure-host scheduler check: decode tokens come off the budget first,
    prefill chunks are FCFS and stop at the budget."""
    pool = PagedKVPool(CFG, PoolConfig(n_pages=32, page_size=4))
    sched = Scheduler(pool, SchedulerConfig(max_decode_batch=4,
                                            token_budget=10,
                                            prefill_chunk=8,
                                            max_pages_per_seq=8))
    a = sched.submit([1] * 20, SamplingParams(max_new_tokens=4), 0.0)
    b = sched.submit([2] * 20, SamplingParams(max_new_tokens=4), 1.0)
    plan = sched.schedule()
    assert plan.decode == []
    # budget 10 -> one 8-token chunk for the FCFS head; b waits until a's
    # prompt is fully scheduled (strict FCFS, no head-of-line skip)
    assert [(r.rid, start, n) for r, start, n in plan.prefill] == \
        [(a.rid, 0, 8)]
    assert b.prefilled == 0
    # unsubmittable request: longer than the block table allows
    with pytest.raises(ValueError):
        sched.submit([0] * 100, SamplingParams(max_new_tokens=1), 2.0)


def test_scheduler_preempted_victim_leaves_decode_plan():
    """When the YOUNGEST running request hits page pressure, the chosen
    victim is an OLDER request that would already sit in the decode list —
    it must be dropped from the plan (decoding it against evicted pages
    would append a garbage token to its output)."""
    pool = PagedKVPool(CFG, PoolConfig(n_pages=4, page_size=4))
    sched = Scheduler(pool, SchedulerConfig(max_decode_batch=2,
                                            token_budget=16,
                                            prefill_chunk=8,
                                            max_pages_per_seq=4))
    a = sched.submit([1] * 3, SamplingParams(max_new_tokens=8), 0.0)
    b = sched.submit([2] * 8, SamplingParams(max_new_tokens=4), 1.0)
    for r, n_pages in ((a, 1), (b, 2)):      # simulate finished prefills
        pool.allocate(n_pages, r.rid)
        r.prefilled = len(r.context)
        r.slot = sched._free_slots.pop(0)
        r.context.append(9)
        r.out_tokens.append(9)
        sched.to_running(r)
    # b's next decode (pos 8) needs a 3rd page; pool is empty -> the only
    # victim is a (older, otherwise already decodable)
    plan = sched.schedule()
    assert plan.decode == [b]
    assert a.preemptions == 1 and a.prefilled == 0
    # the page a freed went straight to b's decode growth, so a's
    # recompute prefill cannot start this step — it waits, pageless
    assert plan.prefill == []
    assert pool.pages_of(a.rid) == [] and a in sched.waiting


def test_submit_rejects_zero_max_new_tokens():
    pool = PagedKVPool(CFG, PoolConfig(n_pages=8, page_size=4))
    sched = Scheduler(pool, SchedulerConfig())
    with pytest.raises(ValueError):
        sched.submit([1, 2], SamplingParams(max_new_tokens=0), 0.0)


def test_pool_schema_abstract_matches_live_state():
    """The dry-run plumbing (steps.pool_abstract_and_shardings) must
    mirror the pool state the engine actually materializes."""
    from repro.launch import steps as S
    from repro.launch.mesh import make_smoke_mesh
    from repro.serving.kv_pool import PoolConfig, init_pool_state
    abs_, shard = S.pool_abstract_and_shardings(CFG, 8, 4,
                                                make_smoke_mesh())
    state = init_pool_state(CFG, PoolConfig(n_pages=8, page_size=4))

    def check(live, spec):
        assert live.shape == spec.shape and live.dtype == spec.dtype
    jax.tree_util.tree_map(check, state, abs_)
    assert (jax.tree_util.tree_structure(shard) ==
            jax.tree_util.tree_structure(abs_))


def test_paged_support_validation():
    with pytest.raises(NotImplementedError):
        M.check_paged_support(CFG.replace(kv_bits=8))
    ssm = ModelConfig(name="mamba2-x", family="ssm", n_layers=2,
                      d_model=64, vocab=128, ssm_state=16, ssm_heads=2,
                      ssm_head_dim=64)
    with pytest.raises(NotImplementedError):
        M.check_paged_support(ssm)
