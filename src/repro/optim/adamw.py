"""Pure-JAX optimizer substrate: AdamW + schedules + gradient utilities.

Built for the scale the dry-run targets:
  * optimizer moments stored in a configurable dtype (bf16 moments halve
    optimizer HBM — the difference between deepseek-v3 fitting a pod or
    not; see DESIGN.md);
  * global-norm clipping;
  * microbatch gradient accumulation lives in launch/steps.py (lax.scan);
  * int8 error-feedback gradient compression for the cross-pod
    all-reduce (distributed-optimization trick: 4x fewer DCN bytes, the
    quantization error is carried into the next step so convergence is
    preserved).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "bfloat16"     # bf16 moments: half the opt-state HBM

    @property
    def mdtype(self):
        return jnp.bfloat16 if self.moment_dtype == "bfloat16" else jnp.float32


class OptState(NamedTuple):
    step: jax.Array
    mu: Any        # first moment (pytree, moment_dtype)
    nu: Any        # second moment (pytree, moment_dtype)


def init_opt_state(params: Any, cfg: OptConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.mdtype)  # noqa: E731
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def cosine_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(params: Any, grads: Any, state: OptState,
                 cfg: OptConfig) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step. Params stay in their storage dtype (f32 master)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.betas
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.mdtype),
                v_new.astype(cfg.mdtype))

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    p_new = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return p_new, OptState(step=step, mu=mu, nu=nu), metrics


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod all-reduce shrink)
# ---------------------------------------------------------------------------

def compress_grads(grads: Any, error: Optional[Any] = None):
    """Quantize gradients to int8 with per-leaf scales + error feedback.

    Returns (q_tree {'q','scale'}, new_error). The caller all-reduces the
    int8 payload across the 'pod' axis (4x fewer DCN bytes than f32), then
    ``decompress_grads``. ``error`` carries this step's quantization
    residual into the next step (standard EF-SGD; keeps convergence).
    """
    if error is None:
        error = jax.tree_util.tree_map(jnp.zeros_like, grads)

    def comp(g, e):
        g = g + e.astype(g.dtype)
        amax = jnp.max(jnp.abs(g)) + 1e-12
        scale = amax / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        err = g - q.astype(g.dtype) * scale
        return {"q": q, "scale": scale}, err

    pairs = jax.tree_util.tree_map(comp, grads, error)
    qs = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return qs, errs


def decompress_grads(qtree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda d: d["q"].astype(jnp.float32) * d["scale"],
        qtree, is_leaf=lambda d: isinstance(d, dict) and "q" in d)
