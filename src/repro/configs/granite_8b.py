"""granite-8b [arXiv:2405.04324; hf]: 36L d=4096 32H (GQA kv=8)
d_ff=14336 vocab=49152 — llama-arch code model (SwiGLU, RMSNorm, tied)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="transformer",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512)
