"""hubert-xlarge [arXiv:2106.07447]: 48L d=1280 16H d_ff=5120 vocab=504 —
encoder-only audio transformer (w2v2 arch). The conv feature extractor is a
STUB per the assignment: ``input_specs`` feeds precomputed frame embeddings
(B, S, d_model). No decode phase exists (encoder-only); decode shape cells
are skipped."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    mlp_type="gelu",
    norm_type="layer",
    use_bias=True,
    frontend_dim=1280,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=56, frontend_dim=64)
