"""deepseek-v3-671b [arXiv:2412.19437; hf]: 61L d=7168 128H vocab=129280,
MoE 1 shared + 256 routed top-8 (expert d_ff=2048, per the assignment's
d_ff), MLA (q_lora 1536, kv_lora 512, qk 128+64 nope+rope, v 128),
first 3 layers dense (d_ff 18432, per the HF config), sigmoid router,
MTP depth 1. Full attention -> ``long_500k`` skipped."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    vocab=129_280,
    d_ff=18432,                  # the 3 leading dense layers
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,               # assignment's d_ff = expert width
    moe_every=1,
    first_dense=3,
    router_type="sigmoid",
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp_depth=1,
)

SMOKE = CONFIG.replace(
    n_layers=3, first_dense=1, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, moe_d_ff=64, n_experts=8, top_k=2, vocab=512,
    q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, mtp_depth=1)
