"""paligemma-3b [arXiv:2407.07726; hf]: 18L d=2048 8H (GQA kv=1)
d_ff=16384 vocab=257216 — SigLIP vision tower + gemma-2b decoder. The
vision tower is a STUB per the assignment: ``input_specs`` provides 256
precomputed patch embeddings (B, 256, d_model) which are prefixed to the
token sequence with a prefix-LM (bidirectional-prefix) mask. Full
attention -> ``long_500k`` skipped."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257_216,
    rope_theta=10_000.0,
    mlp_type="geglu",
    tie_embeddings=True,
    n_prefix=256,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512, n_prefix=4)
