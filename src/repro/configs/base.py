"""Unified model/run configuration for all architecture families."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # transformer | moe | hybrid | ssm | encoder | vlm
    n_layers: int
    d_model: int
    vocab: int

    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 -> full attention
    global_every: int = 0            # gemma3: every k-th layer is global
    causal: bool = True

    # mlp
    d_ff: int = 0
    mlp_type: str = "swiglu"         # swiglu | geglu | gelu (non-gated)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1               # apply MoE every k-th layer
    first_dense: int = 0             # leading dense layers (deepseek-v3: 3)
    capacity_factor: float = 1.0
    router_type: str = "softmax"     # softmax | sigmoid (deepseek-v3)

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MTP (deepseek-v3)
    mtp_depth: int = 0

    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    expand: int = 2
    attn_every: int = 0              # jamba: every k-th layer is attention

    # VLM / encoder frontends (stubs per assignment: precomputed embeddings)
    n_prefix: int = 0                # image patches (paligemma) / 0
    frontend_dim: int = 0            # hubert frame-embedding dim

    # numerics
    dtype: str = "bfloat16"
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    norm_type: str = "rms"           # rms | layer (starcoder2, hubert)
    use_bias: bool = False           # linear biases (starcoder2, hubert)
    use_qk_norm: bool = False        # gemma3 per-head q/k RMSNorm

    # SPARQLe quantized serving
    w_bits: int = 4
    kv_bits: int = 4

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def cdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# smoke-test shape (CPU, reduced configs)
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
