"""jamba-v0.1-52b [arXiv:2403.19887; hf]: 32L d=4096 32H (GQA kv=8)
d_ff=14336 vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave,
MoE every 2nd layer. The Mamba mixer is realized with the SSD block
(DESIGN.md: Mamba-1's selective scan is the head_dim-1 special case of SSD;
the hybrid structure is what Jamba contributes). ``long_500k`` runs: 7/8 of
layers are O(1)-state SSM; the 4 attention layers are O(L) per token."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    attn_every=8,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    expand=2,
)

SMOKE = CONFIG.replace(
    n_layers=8, attn_every=8, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, moe_d_ff=128, vocab=512, n_experts=4, top_k=2,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
