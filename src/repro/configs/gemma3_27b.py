"""gemma3-27b [hf:google/gemma-3-*-pt]: 62L d=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144 — 5:1 local:global attention (1024 sliding window,
global layers at rope theta 1M), qk-norm, GeGLU, tied embeddings, 128k ctx.
``long_500k`` runs: 5/6 of layers are sliding-window (sub-quadratic); the
1-in-6 global layers are O(L) per decoded token."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="transformer",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262_144,
    rope_theta=10_000.0,          # local layers; global layers use 1e6
    sliding_window=1024,
    global_every=6,               # 5 local : 1 global
    mlp_type="geglu",
    use_qk_norm=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=6, global_every=3, sliding_window=16,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512)
