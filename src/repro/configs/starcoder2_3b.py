"""starcoder2-3b [arXiv:2402.19173; hf]: 30L d=3072 24H (GQA kv=2)
d_ff=12288 vocab=49152 — GQA, RoPE, layernorm+bias, non-gated GELU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="transformer",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    rope_theta=999_999.0,
    mlp_type="gelu",
    norm_type="layer",
    use_bias=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512)
