"""mamba2-2.7b [arXiv:2405.21060]: 64L d=2560 vocab=50280 ssm_state=128 —
attention-free SSD (state-space duality). d_inner = 2*2560 = 5120, 80 heads
of dim 64, 1 B/C group, conv width 4. Sub-quadratic by construction:
``long_500k`` runs with O(1) per-token state."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    conv_width=4,
    expand=2,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16)
