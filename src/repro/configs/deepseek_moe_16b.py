"""deepseek-moe-16b [arXiv:2401.06066; hf]: 28L d=2048 16H vocab=102400,
fine-grained MoE: 2 shared + 64 routed top-6 (expert d_ff=1408), first
layer dense (d_ff 10944). MHA (kv=16). Full attention -> long_500k skip."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    vocab=102_400,
    d_ff=10944,                  # leading dense layer
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    moe_every=1,
    first_dense=1,
    router_type="softmax",
)

SMOKE = CONFIG.replace(
    n_layers=3, first_dense=1, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, moe_d_ff=32, n_experts=8, n_shared_experts=2,
    top_k=2, vocab=512)
