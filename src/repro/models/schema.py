"""Parameter schema: declare params once, derive init / shardings / abstract.

Each model family builds a nested dict of :class:`ParamSpec` (shape + logical
axes + init scale). From the schema we derive:

  * ``init_params``   — materialized arrays (smoke tests, real training),
  * ``abstract_params`` — ShapeDtypeStructs (the dry-run lowers 671B-param
    models without allocating a byte),
  * ``param_pspecs``  — PartitionSpecs via the logical-axis rule table,
  * ``param_shardings`` — NamedShardings for jit in_shardings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import spec_for


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis per dim
    dtype: jnp.dtype = jnp.float32
    init: str = "normal"              # normal | zeros | ones | embed
    scale: Optional[float] = None     # overrides fan-in scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = Dict[str, object]  # nested dict of ParamSpec


def _fan_in(shape: Tuple[int, ...]) -> int:
    # last dim is output features by convention; fan-in = prod of the rest
    if len(shape) <= 1:
        return max(1, shape[0] if shape else 1)
    f = 1
    for d in shape[:-1]:
        f *= d
    return f


def _init_leaf(key, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 1.0
        return (jax.random.normal(key, spec.shape, jnp.float32) * std
                ).astype(spec.dtype)
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(
        _fan_in(spec.shape))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std
            ).astype(spec.dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map_schema(schema: Schema, fn: Callable[[str, ParamSpec], object],
                prefix: str = "") -> Dict:
    out = {}
    for k, v in schema.items():
        path = f"{prefix}/{k}" if prefix else k
        if _is_spec(v):
            out[k] = fn(path, v)
        else:
            out[k] = _map_schema(v, fn, path)
    return out


def init_params(schema: Schema, key: jax.Array) -> Dict:
    leaves = []
    _map_schema(schema, lambda p, s: leaves.append(p) or p)
    keys = dict(zip(sorted(leaves),
                    jax.random.split(key, max(1, len(leaves)))))
    return _map_schema(schema, lambda p, s: _init_leaf(keys[p], s))


def abstract_params(schema: Schema) -> Dict:
    return _map_schema(
        schema, lambda p, s: jax.ShapeDtypeStruct(s.shape, s.dtype))


def param_pspecs(schema: Schema, mesh: Mesh) -> Dict:
    return _map_schema(
        schema, lambda p, s: spec_for(s.axes, s.shape, mesh))


def param_shardings(schema: Schema, mesh: Mesh) -> Dict:
    return _map_schema(
        schema,
        lambda p, s: NamedSharding(mesh, spec_for(s.axes, s.shape, mesh)))


def param_count(schema: Schema) -> int:
    total = [0]

    def add(p, s):
        n = 1
        for d in s.shape:
            n *= d
        total[0] += n
        return None

    _map_schema(schema, add)
    return total[0]


def param_bytes(schema: Schema) -> int:
    total = [0]

    def add(p, s):
        n = np.dtype(s.dtype).itemsize
        for d in s.shape:
            n *= d
        total[0] += n
        return None

    _map_schema(schema, add)
    return total[0]
