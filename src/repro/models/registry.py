"""Architecture registry: ``--arch <id>`` -> config, schemas, input specs.

One entry per assigned architecture. Provides everything the launchers and
the dry-run need: full/smoke configs, float + SPARQLe-quantized parameter
schemas, abstract input ShapeDtypeStructs per (shape-cell, step kind), and
abstract KV/SSM cache trees for decode cells. The cell plan (which of the
4 assigned shapes run vs. skip, and why) lives here as the single source
of truth for the dry-run and EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (deepseek_moe_16b, deepseek_v3_671b, gemma3_27b,
                           granite_8b, hubert_xlarge, jamba_v01_52b,
                           mamba2_2p7b, paligemma_3b, starcoder2_3b, yi_6b)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models.schema import ParamSpec, Schema
from repro.models.schema_builder import build_schema
from repro.models.stages import build_stages

_MODULES = [starcoder2_3b, granite_8b, gemma3_27b, yi_6b, hubert_xlarge,
            jamba_v01_52b, deepseek_v3_671b, deepseek_moe_16b,
            paligemma_3b, mamba2_2p7b]

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES: Dict[str, ModelConfig] = {m.CONFIG.name: m.SMOKE for m in _MODULES}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


# ---------------------------------------------------------------------------
# cell plan: which assigned shapes run for each arch
# ---------------------------------------------------------------------------

def cell_plan(name: str) -> List[Tuple[str, bool, str]]:
    """[(shape, runs, reason)] for all four assigned shapes."""
    cfg = get_config(name)
    sub_quadratic = cfg.family in ("ssm", "hybrid") or bool(cfg.global_every)
    plan = []
    for sname, shp in SHAPES.items():
        if cfg.family == "encoder" and shp.kind == "decode":
            plan.append((sname, False, "encoder-only: no autoregressive step"))
        elif sname == "long_500k" and not sub_quadratic:
            plan.append((sname, False, "pure full attention: 500k decode "
                                       "requires sub-quadratic attention"))
        else:
            plan.append((sname, True, ""))
    return plan


def runnable_cells() -> List[Tuple[str, str]]:
    cells = []
    for name in ARCHS:
        for sname, runs, _ in cell_plan(name):
            if runs:
                cells.append((name, sname))
    return cells


# ---------------------------------------------------------------------------
# abstract inputs per (arch, shape, kind)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                kind: Optional[str] = None) -> Dict[str, Any]:
    """Abstract model inputs for one shape cell.

    ``kind`` defaults to the shape's own kind. For 'train' the dict has
    tokens/frames/patches + targets; 'prefill' drops targets; 'decode'
    returns {token, pos} (the cache is built by :func:`cache_specs`).
    """
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.cdtype
    if kind == "decode":
        return {"token": _sds((b,), jnp.int32), "pos": _sds((b,), jnp.int32)}
    spec: Dict[str, Any] = {}
    if cfg.family == "encoder":
        spec["frames"] = _sds((b, s, cfg.d_model), dt)
    elif cfg.family == "vlm":
        spec["patches"] = _sds((b, cfg.n_prefix, cfg.d_model), dt)
        spec["tokens"] = _sds((b, s - cfg.n_prefix), jnp.int32)
    else:
        spec["tokens"] = _sds((b, s), jnp.int32)
    if kind == "train":
        tgt_s = s - cfg.n_prefix if cfg.family == "vlm" else s
        spec["targets"] = _sds((b, tgt_s), jnp.int32)
    return spec


# ---------------------------------------------------------------------------
# abstract cache trees (as ParamSpec trees -> shardings derivable)
# ---------------------------------------------------------------------------

def cache_schema(cfg: ModelConfig, batch: int, max_len: int) -> Schema:
    """ParamSpec tree mirroring the cache pytree prefill/decode use."""
    b, smax = batch, max_len
    dt = cfg.cdtype

    # kv_bits==4 packs two nibbles per int8 byte (model._kv_quant)
    pack = 2 if cfg.kv_bits == 4 else 1

    def layer_cache(ld) -> Schema:
        if ld.mixer == "attn":
            kvh, hd = cfg.n_kv_heads, cfg.hd
            return {
                "k_q": ParamSpec((b, smax, kvh, hd // pack),
                                 ("batch", "kv_seq", "kv_heads", None),
                                 jnp.int8, init="zeros"),
                "k_s": ParamSpec((b, smax, kvh),
                                 ("batch", "kv_seq", "kv_heads"),
                                 jnp.float32, init="ones"),
                "v_q": ParamSpec((b, smax, kvh, hd // pack),
                                 ("batch", "kv_seq", "kv_heads", None),
                                 jnp.int8, init="zeros"),
                "v_s": ParamSpec((b, smax, kvh),
                                 ("batch", "kv_seq", "kv_heads"),
                                 jnp.float32, init="ones"),
            }
        if ld.mixer == "mla":
            return {
                "ckv_q": ParamSpec((b, smax, cfg.kv_lora_rank // pack),
                                   ("batch", "kv_seq", None),
                                   jnp.int8, init="zeros"),
                "ckv_s": ParamSpec((b, smax), ("batch", "kv_seq"),
                                   jnp.float32, init="ones"),
                "kr": ParamSpec((b, smax, cfg.qk_rope_dim),
                                ("batch", "kv_seq", None), dt, init="zeros"),
            }
        # ssd
        din = cfg.d_inner
        g, n, p_ = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
        nh = din // p_
        conv_ch = din + 2 * g * n
        return {
            "h": ParamSpec((b, g, nh // g, p_, n),
                           ("batch", None, "heads", None, None),
                           jnp.float32, init="zeros"),
            "conv": ParamSpec((b, cfg.conv_width - 1, conv_ch),
                              ("batch", None, "mlp"), dt, init="zeros"),
        }

    def stack(tree: Schema, repeat: int) -> Schema:
        return {k: (stack(v, repeat) if isinstance(v, dict) else
                    ParamSpec((repeat,) + v.shape, ("layers",) + v.axes,
                              v.dtype, v.init, v.scale))
                for k, v in tree.items()}

    stages: Schema = {}
    for si, stage in enumerate(build_stages(cfg)):
        stages[f"s{si}"] = {
            f"p{pi}": stack(layer_cache(ld), stage.repeat)
            for pi, ld in enumerate(stage.period)}
    return {"stages": stages}


def model_schema(cfg: ModelConfig) -> Schema:
    return build_schema(cfg)


# re-exported conveniences -------------------------------------------------

def describe(name: str) -> Dict[str, Any]:
    from repro.models.schema import param_count
    cfg = get_config(name)
    n = param_count(build_schema(cfg))
    return {
        "name": name, "family": cfg.family, "layers": cfg.n_layers,
        "d_model": cfg.d_model, "params_b": round(n / 1e9, 2),
        "cells": cell_plan(name),
    }
