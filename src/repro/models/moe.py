"""Mixture-of-Experts layer: sort-based token dispatch, expert parallelism.

Dispatch strategy (scales to 256 experts x 1M tokens, unlike GShard's
(T, E, C) one-hot einsum): flatten the (token, expert-choice) assignments,
``argsort`` them by expert id, compute each assignment's rank within its
expert via a vectorized ``searchsorted``, drop ranks beyond capacity, and
scatter tokens into a contiguous (E, C, D) buffer. Experts are sharded over
the "model" mesh axis, capacity slots over "data" — XLA inserts the
all-to-alls at the dispatch/combine boundaries.

Supports softmax routing (jamba/deepseek-moe) and sigmoid routing with
normalized top-k (deepseek-v3), plus always-on shared experts.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core.qlinear import expert_linear, linear
from repro.distributed.sharding import active_mesh, constrain


def router(x: jax.Array, w_router: jax.Array, router_type: str,
           top_k: int) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D) -> (weights (T, k), expert_ids (T, k))."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    if router_type == "sigmoid":          # deepseek-v3
        scores = jax.nn.sigmoid(logits)
        topv, topi = jax.lax.top_k(scores, top_k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, top_k)
    return topv, topi


def load_balance_loss(x: jax.Array, w_router: jax.Array, top_k: int) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (training substrate)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    e = probs.shape[-1]
    _, topi = jax.lax.top_k(probs, top_k)
    counts = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = probs.mean(axis=0)
    return e * jnp.sum(frac_tokens * frac_probs)


def moe_ffn(
    x: jax.Array,             # (T, D) flattened tokens
    w_router: jax.Array,      # (D, E)
    w_gate: jax.Array,        # (E, D, F)
    w_up: jax.Array,          # (E, D, F)
    w_down: jax.Array,        # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.0,
    router_type: str = "softmax",
) -> jax.Array:
    t, d = x.shape
    e = w_router.shape[-1]
    capacity = max(1, int(t * top_k * capacity_factor) // e)

    topv, topi = router(x, w_router, router_type, top_k)

    flat_e = topi.reshape(-1)                       # (T*k,) expert per assignment
    flat_w = topv.reshape(-1).astype(jnp.float32)
    flat_t = jnp.arange(t * top_k, dtype=jnp.int32) // top_k

    order = jnp.argsort(flat_e)                     # stable
    se = flat_e[order]
    st = flat_t[order]
    sw = flat_w[order]
    # rank within expert = index - first index of this expert id
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = rank < capacity
    slot = jnp.where(keep, se.astype(jnp.int32) * capacity + rank,
                     e * capacity)                  # dropped -> overflow row

    xs = jnp.take(x, st, axis=0)                    # (T*k, D) tokens, sorted
    buf = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].set(xs)
    expert_in = buf[:-1].reshape(e, capacity, d)
    expert_in = constrain(expert_in, ("experts", "capacity", None))

    # expert FFN (SwiGLU), batched over the expert dim; expert_linear
    # dispatches between float weights and SPARQLe-quantized experts
    h = jax.nn.silu(expert_linear(expert_in, w_gate))
    h = h * expert_linear(expert_in, w_up)
    h = constrain(h, ("experts", "capacity", "mlp"))
    # under serving TP the experts are sharded on their hidden dim (NOT
    # the expert axis): dispatch/routing replicate, and the down-proj's
    # single int32 psum keeps the combine bit-exact vs a single device
    expert_out = expert_linear(h, w_down, tp="row")
    expert_out = constrain(expert_out, ("experts", "capacity", None))

    # combine via the INVERSE permutation (pure gathers): a scatter-add here
    # lowers to an SPMD scatter whose (f32 + u32) all-reduce pair over the
    # expert axis doubles combine traffic (§Perf iteration). inv_order[a]
    # is the sorted position of assignment a = t*top_k + kk.
    inv_order = jnp.argsort(order)
    gathered = expert_out.reshape(e * capacity, d)[
        jnp.minimum(slot, e * capacity - 1)]
    gathered = gathered * (sw * keep)[:, None]
    per_assignment = gathered[inv_order].reshape(t, top_k, d)
    return per_assignment.sum(axis=1).astype(x.dtype)


def moe_ffn_local_ep(
    x_l: jax.Array,            # (T_local, D) this data-shard's tokens
    w_router: jax.Array,       # (D, E_total) replicated
    w_gate, w_up, w_down,      # (E_local, ...) — THIS shard's experts
    *,
    top_k: int,
    e_total: int,
    model_axis: str,
    capacity_factor: float = 1.0,
    router_type: str = "softmax",
) -> jax.Array:
    """Expert-parallel MoE body (runs inside a fully-manual shard_map).

    Each model shard owns ``E_local = E_total / model_ways`` experts and
    holds the data shard's tokens replicated. It routes against the FULL
    router, dispatches only assignments that hit its own experts into a
    local (E_local*C, D) buffer (all local memory traffic), runs its
    expert FFNs, combines its partial outputs per token, and a single
    ``psum`` over the model axis produces the final combine — the one
    irreducible MoE reduction (T_local x D), instead of GSPMD's
    replicated (T*k x D) scatter/gather all-reduce pairs (§Perf log).
    """
    t, d = x_l.shape
    e_local = w_gate.shape[0] if not hasattr(w_gate, "w") else \
        w_gate.w.q.shape[0]
    m_idx = jax.lax.axis_index(model_axis)
    off = m_idx * e_local
    capacity = max(1, int(t * top_k * capacity_factor) // e_total)

    topv, topi = router(x_l, w_router, router_type, top_k)

    flat_g = topi.reshape(-1)                        # global expert ids
    mine = (flat_g >= off) & (flat_g < off + e_local)
    flat_e = jnp.where(mine, flat_g - off, e_local)  # foreign -> overflow
    flat_w = (topv.reshape(-1) * mine).astype(jnp.float32)
    flat_t = jnp.arange(t * top_k, dtype=jnp.int32) // top_k

    order = jnp.argsort(flat_e)
    se = flat_e[order]
    st = flat_t[order]
    sw = flat_w[order]
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = (rank < capacity) & (se < e_local)
    slot = jnp.where(keep, se.astype(jnp.int32) * capacity + rank,
                     e_local * capacity - 1)

    xs = jnp.take(x_l, st, axis=0)
    xs = jnp.where(keep[:, None], xs, 0)
    buf = jnp.zeros((e_local * capacity, d), x_l.dtype).at[slot].add(xs)
    expert_in = buf.reshape(e_local, capacity, d)

    h = jax.nn.silu(expert_linear(expert_in, w_gate))
    h = h * expert_linear(expert_in, w_up)
    expert_out = expert_linear(h, w_down)

    gathered = expert_out.reshape(e_local * capacity, d)[slot]
    gathered = gathered * (sw * keep)[:, None]
    inv_order = jnp.argsort(order)
    per_assign = gathered[inv_order].reshape(t, top_k, d)
    y_partial = per_assign.sum(axis=1)
    return jax.lax.psum(y_partial.astype(jnp.float32),
                        model_axis).astype(x_l.dtype)


def moe_ffn_dist(
    x: jax.Array,
    w_router, w_gate, w_up, w_down,
    *,
    top_k: int,
    capacity_factor: float = 1.0,
    router_type: str = "softmax",
) -> jax.Array:
    """Distribution-aware MoE: manual expert parallelism via shard_map.

    The sort/scatter dispatch is data-dependent, so GSPMD cannot partition
    it — left alone it replicates (T*k, D) buffers on every device (the
    "involuntary full rematerialization" failure mode). The scalable
    formulation — what Tutel/DeepSpeed-MoE/MaxText do — is hierarchical:
    tokens are manual over the batch axes ("pod", "data"); experts are
    manual over "model" (E_total/model_ways per shard, weights never
    gathered over model); each shard dispatches locally and one psum over
    "model" performs the combine (see :func:`moe_ffn_local_ep`).
    """
    mesh = active_mesh()
    t = x.shape[0]
    e_total = w_router.shape[-1]
    data_axes = tuple(a for a in ("pod", "data")
                      if mesh is not None and mesh.shape.get(a, 1) > 1)
    nshards = 1
    for a in data_axes:
        nshards *= mesh.shape[a]
    model_ways = mesh.shape.get("model", 1) if mesh is not None else 1
    if (not data_axes or t % nshards != 0 or model_ways <= 1
            or e_total % model_ways != 0):
        return moe_ffn(x, w_router, w_gate, w_up, w_down, top_k=top_k,
                       capacity_factor=capacity_factor,
                       router_type=router_type)

    chunk = 16384  # bounds local dispatch buffers to ~chunk*k*D bytes

    def local(x_l, wr, wg, wu, wd):
        def one(xi):
            return moe_ffn_local_ep(
                xi, wr, wg, wu, wd, top_k=top_k, e_total=e_total,
                model_axis="model", capacity_factor=capacity_factor,
                router_type=router_type)

        t_l = x_l.shape[0]
        if t_l <= chunk or t_l % chunk != 0:
            return one(x_l)
        xc = x_l.reshape(t_l // chunk, chunk, x_l.shape[-1])
        return jax.lax.map(one, xc).reshape(t_l, x_l.shape[-1])

    def wspec(w):
        return jax.tree_util.tree_map(
            lambda leaf: P(*(("model",) + (None,) * (leaf.ndim - 1)))
            if leaf.ndim > 0 else P(), w)

    spec_x = P(data_axes if len(data_axes) > 1 else data_axes[0])
    manual = frozenset(data_axes) | {"model"}
    in_specs = (spec_x, P(), wspec(w_gate), wspec(w_up), wspec(w_down))
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(
            local, mesh=mesh, axis_names=manual,
            in_specs=in_specs, out_specs=spec_x, check_vma=False)
    else:  # jax < 0.6: experimental API; manual axes = complement of auto
        from jax.experimental.shard_map import shard_map
        fn = shard_map(
            local, mesh=mesh, in_specs=in_specs, out_specs=spec_x,
            check_rep=False, auto=frozenset(mesh.axis_names) - manual)
    return fn(x, w_router, w_gate, w_up, w_down)


def shared_expert_ffn(x, w_gate, w_up, w_down):
    """Always-on shared expert(s) — a plain SwiGLU over (possibly) stacked
    shared-expert weights folded into one wide FFN."""
    h = jax.nn.silu(linear(x, w_gate)) * linear(x, w_up)
    h = constrain(h, ("batch", "seq", "mlp"))
    return linear(h, w_down, tp="row")
