"""Layer-stacking plan: stages of scanned periods.

Every architecture is expressed as a list of ``Stage``s; each stage scans a
*period* — a short, fixed sequence of heterogeneous sub-layers — ``repeat``
times with stacked parameters. This keeps the HLO O(period-length) in model
depth while supporting heterogeneous interleaves exactly:

  * dense transformer:  [attn+dense] x n_layers
  * gemma3 (5 local : 1 global): period of 6 sub-layers (5 sliding-window +
    1 global, different rope theta), repeated 10x, + a 2-layer tail stage
  * jamba (1 attn : 7 mamba, MoE every 2nd): one 8-sub-layer period x 4
  * deepseek-v3 (3 dense + 58 MoE): stage [mla+dense] x 3, stage [mla+moe] x 58
  * mamba2: [ssd] x 64
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class LayerDef:
    mixer: str                  # "attn" | "mla" | "ssd"
    ffn: str                    # "dense" | "moe" | "none"
    window: int = 0             # 0 = full attention
    rope_theta: float = 0.0     # 0 -> cfg.rope_theta


@dataclasses.dataclass(frozen=True)
class Stage:
    period: List[LayerDef]
    repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.period) * self.repeat


def build_stages(cfg: ModelConfig) -> List[Stage]:
    fam = cfg.family
    if fam in ("transformer", "encoder", "vlm"):
        if cfg.global_every:  # gemma3: (global_every-1) local then 1 global
            ge = cfg.global_every
            period = [LayerDef("attn", "dense", window=cfg.sliding_window)
                      for _ in range(ge - 1)]
            period += [LayerDef("attn", "dense", window=0, rope_theta=1e6)]
            n_full, tail = divmod(cfg.n_layers, ge)
            stages = [Stage(period, n_full)]
            if tail:
                stages.append(Stage(
                    [LayerDef("attn", "dense", window=cfg.sliding_window)
                     for _ in range(tail)], 1))
            return stages
        return [Stage([LayerDef("attn", "dense",
                                window=cfg.sliding_window)], cfg.n_layers)]

    if fam == "moe":
        mixer = "mla" if cfg.use_mla else "attn"
        stages = []
        if cfg.first_dense:
            stages.append(Stage([LayerDef(mixer, "dense")], cfg.first_dense))
        n_moe = cfg.n_layers - cfg.first_dense
        if cfg.moe_every > 1:
            period = []
            for i in range(cfg.moe_every):
                period.append(LayerDef(
                    mixer, "moe" if i % cfg.moe_every == cfg.moe_every - 1
                    else "dense"))
            stages.append(Stage(period, n_moe // cfg.moe_every))
        else:
            stages.append(Stage([LayerDef(mixer, "moe")], n_moe))
        return stages

    if fam == "hybrid":  # jamba: period of attn_every layers, 1 attn + rest ssd
        ae = cfg.attn_every or 8
        period = []
        for i in range(ae):
            mixer = "attn" if i == ae // 2 else "ssd"
            ffn = "moe" if (cfg.n_experts and i % cfg.moe_every ==
                            cfg.moe_every - 1) else "dense"
            period.append(LayerDef(mixer, ffn))
        assert cfg.n_layers % ae == 0, (cfg.n_layers, ae)
        return [Stage(period, cfg.n_layers // ae)]

    if fam == "ssm":
        return [Stage([LayerDef("ssd", "none")], cfg.n_layers)]

    raise ValueError(f"unknown family {fam}")


def total_layers(stages: List[Stage]) -> int:
    return sum(s.n_layers for s in stages)
