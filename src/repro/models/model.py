"""Unified functional model: one forward/prefill/decode for all families.

Architecture = embedding + a list of scanned stages (``stages.py``) + final
norm + head. Each stage scans a *period* of heterogeneous sub-layers with
stacked params, so the HLO is O(period) in depth. Mixers: GQA attention
(full / sliding-window / global, RoPE, optional qk-norm), MLA (deepseek —
*absorbed* compressed-KV attention, see note below), and Mamba-2 SSD.
FFNs: dense (SwiGLU / GeGLU / GELU), MoE (shared + routed), or none.

Every projection goes through :func:`repro.core.qlinear.linear`, so the same
code serves float params (training) and SPARQLe-quantized params (the
paper's sub-precision serving path) — the technique is a first-class,
zero-code-change feature of the framework.

MLA note (DESIGN.md §2): we use the weight-absorbed form everywhere —
attention scores are computed directly against the compressed KV cache
(c_kv, k_rope), never materializing per-head K/V. This is mandatory at
decode (naive expansion of a 32k-token cache for 128 heads is ~100s of GB)
and memory-safe at prefill at the cost of extra score/context FLOPs
(contraction over kv_lora_rank instead of head_dim); the blockwise
re-materialized prefill variant is tracked as a §Perf iteration.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import linear, msb_skip_scope
from repro.core.quantize import quantize_weights
from repro.distributed.sharding import constrain
from repro.distributed.tp import tp_ctx
from repro.models import moe as moe_lib
from repro.models import ssd as ssd_lib
from repro.models.layers import (AttnSpec, NEG_INF, act_wire_telemetry,
                                 decode_attention, embed, flash_attention,
                                 layer_norm, rms_norm, rope,
                                 stack_sublayer_telemetry)
from repro.models.stages import LayerDef, Stage, build_stages

Params = Dict[str, Any]
Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layer":
        return layer_norm(x, p["gamma"], p["beta"], cfg.rms_eps)
    return rms_norm(x, p["gamma"], cfg.rms_eps)


def _kv_quant(cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantize one KV tensor (..., hd) -> (container, f32 scale).

    For kv_bits == 4 the two's-complement nibbles are PACKED two-per-byte
    (..., hd/2), halving KV-cache HBM/footprint for real — the sub-byte
    packing the paper's wire format implies, applied to the cache
    (§Perf iteration: decode cells are cache-bandwidth-bound).
    """
    qt = quantize_weights(x, bits=cfg.kv_bits, axis=-1)
    q = qt.q
    if cfg.kv_bits == 4 and q.shape[-1] % 2 == 0:
        lo = jnp.bitwise_and(q[..., 0::2], 0xF)
        hi = jnp.left_shift(jnp.bitwise_and(q[..., 1::2], 0xF), 4)
        q = jnp.bitwise_or(lo, hi).astype(jnp.int8)
    return q, qt.scale[..., 0]


def _kv_dequant(cfg: ModelConfig, q: jax.Array, s: jax.Array,
                dtype) -> jax.Array:
    if cfg.kv_bits == 4:
        # unpack two's-complement nibbles: (x << 4) >> 4 sign-extends
        lo = jnp.right_shift(jnp.left_shift(q, 4), 4)
        hi = jnp.right_shift(q, 4)
        q = jnp.stack([lo, hi], axis=-1).reshape(*q.shape[:-1],
                                                 q.shape[-1] * 2)
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# GQA attention mixer
# ---------------------------------------------------------------------------

def _attn_qkv(cfg: ModelConfig, p: Params, h: jax.Array, positions,
              theta: float):
    """h (..., D) -> q (..., H, hd), k/v (..., KVH, hd), roped."""
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(h, p["wq"], p.get("bq"))
    k = linear(h, p["wk"], p.get("bk"))
    v = linear(h, p["wv"], p.get("bv"))
    q = q.reshape(*q.shape[:-1], H, hd)
    k = k.reshape(*k.shape[:-1], KVH, hd)
    v = v.reshape(*v.shape[:-1], KVH, hd)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


def attn_full(cfg: ModelConfig, ld: LayerDef, p: Params, x: jax.Array,
              positions: jax.Array, prefix_len: int,
              make_cache: Optional[int]) -> Tuple[jax.Array, Optional[Cache]]:
    """Training / prefill attention over the whole sequence."""
    b, s, d = x.shape
    theta = ld.rope_theta or cfg.rope_theta
    h = _norm(cfg, p["ln"], x)
    q, k, v = _attn_qkv(cfg, p, h, positions, theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    spec = AttnSpec(causal=cfg.causal, window=ld.window,
                    prefix_len=prefix_len)
    o = flash_attention(q, k, v, spec)
    o = o.reshape(b, s, cfg.n_heads * cfg.hd)
    out = linear(o, p["wo"], p.get("bo"), tp="row")

    cache = None
    if make_cache is not None:
        smax = make_cache
        kq, ks = _kv_quant(cfg, k)
        vq, vs = _kv_quant(cfg, v)
        pad = [(0, 0), (0, smax - s), (0, 0), (0, 0)]
        pad3 = [(0, 0), (0, smax - s), (0, 0)]
        cache = {
            "k_q": jnp.pad(kq, pad), "k_s": jnp.pad(ks, pad3),
            "v_q": jnp.pad(vq, pad), "v_s": jnp.pad(vs, pad3),
        }
    return out, cache


def attn_decode(cfg: ModelConfig, ld: LayerDef, p: Params, x: jax.Array,
                cache: Cache, pos: jax.Array) -> Tuple[jax.Array, Cache]:
    """One-token attention against the quantized KV cache. x: (B, D)."""
    b, d = x.shape
    theta = ld.rope_theta or cfg.rope_theta
    h = _norm(cfg, p["ln"], x)
    q, k_new, v_new = _attn_qkv(cfg, p, h, pos, theta)
    # insert the new token's quantized K/V at its position
    bidx = jnp.arange(b)
    kq, ks = _kv_quant(cfg, k_new)
    vq, vs = _kv_quant(cfg, v_new)
    cache = {
        "k_q": cache["k_q"].at[bidx, pos].set(kq),
        "k_s": cache["k_s"].at[bidx, pos].set(ks),
        "v_q": cache["v_q"].at[bidx, pos].set(vq),
        "v_s": cache["v_s"].at[bidx, pos].set(vs),
    }
    k = _kv_dequant(cfg, cache["k_q"], cache["k_s"], x.dtype)
    v = _kv_dequant(cfg, cache["v_q"], cache["v_s"], x.dtype)
    k = constrain(k, ("batch", "kv_seq", "kv_heads", None))
    v = constrain(v, ("batch", "kv_seq", "kv_heads", None))
    spec = AttnSpec(causal=cfg.causal, window=ld.window)
    o = decode_attention(q, k, v, pos, spec)
    o = o.reshape(b, cfg.n_heads * cfg.hd)
    return linear(o, p["wo"], p.get("bo"), tp="row"), cache


# ---------------------------------------------------------------------------
# MLA mixer (deepseek) — absorbed compressed-KV attention
# ---------------------------------------------------------------------------

def _mla_q(cfg: ModelConfig, p: Params, h: jax.Array, positions):
    """h (..., D) -> q_nope (..., H, dn), q_rope (..., H, dr)."""
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = linear(h, p["wq_a"])
    cq = rms_norm(cq, p["q_norm"], cfg.rms_eps)
    q = linear(cq, p["wq_b"]).reshape(*h.shape[:-1], H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _mla_ckv(cfg: ModelConfig, p: Params, h: jax.Array, positions):
    """h (..., D) -> compressed c_kv (..., rkv), roped shared k_rope (..., dr)."""
    rkv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv_full = linear(h, p["wkv_a"])
    ckv, kr = ckv_full[..., :rkv], ckv_full[..., rkv:]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.rms_eps)
    kr = rope(kr[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return ckv, kr


def _mla_absorbed_weights(cfg: ModelConfig, p: Params):
    """Split wkv_b into W_uk (rkv, H, dn) and W_uv (rkv, H, dv).

    SPARQLe-quantized wkv_b is applied through its dequantized form here —
    absorption is a float-domain rewrite (noted in DESIGN.md: the absorbed
    matmuls contract activations x activations, the paper's out-of-scope
    case, so they stay unquantized).
    """
    w = p["wkv_b"]
    if not isinstance(w, jax.Array):          # SparqleLinear (maybe packed)
        w = w.dequantize()
    H, dn, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    w = w.reshape(cfg.kv_lora_rank, H, dn + dv)
    return w[..., :dn], w[..., dn:]


def mla_full(cfg: ModelConfig, ld: LayerDef, p: Params, x: jax.Array,
             positions: jax.Array, prefix_len: int,
             make_cache: Optional[int]) -> Tuple[jax.Array, Optional[Cache]]:
    b, s, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = _norm(cfg, p["ln"], x)
    qn, qr = _mla_q(cfg, p, h, positions)          # (B,S,H,dn/dr)
    ckv, kr = _mla_ckv(cfg, p, h, positions)       # (B,S,rkv) / (B,S,dr)
    w_uk, w_uv = _mla_absorbed_weights(cfg, p)

    o = _mla_flash(qn, qr, ckv, kr, w_uk, w_uv, causal=cfg.causal)
    out = linear(o.reshape(b, s, H * dv), p["wo"])

    cache = None
    if make_cache is not None:
        smax = make_cache
        cq, cs = _kv_quant(cfg, ckv)
        cache = {
            "ckv_q": jnp.pad(cq, [(0, 0), (0, smax - s), (0, 0)]),
            "ckv_s": jnp.pad(cs, [(0, 0), (0, smax - s)]),
            "kr": jnp.pad(kr, [(0, 0), (0, smax - s), (0, 0)]),
        }
    return out, cache


def _mla_flash(qn, qr, ckv, kr, w_uk, w_uv, *, causal: bool,
               bq: int = 512, bkv: int = 1024) -> jax.Array:
    """Blockwise absorbed MLA attention. Returns (B, S, H, dv)."""
    b, s_orig, H, dn = qn.shape
    rkv = ckv.shape[-1]
    dr = qr.shape[-1]
    dv = w_uv.shape[-1]
    scale = (dn + dr) ** -0.5
    bq = min(bq, s_orig)
    bkv = min(bkv, s_orig)
    pad = max((-s_orig) % bq, (-s_orig) % bkv)
    if pad:  # tail-pad; causal masking hides padded KV from valid queries
        assert causal, "non-causal MLA would attend padded positions"
        padfn = lambda t: jnp.pad(  # noqa: E731
            t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        qn, qr, ckv, kr = map(padfn, (qn, qr, ckv, kr))
    s = s_orig + pad
    n_q, n_kv = s // bq, s // bkv

    qn_b = qn.reshape(b, n_q, bq, H, dn).transpose(1, 0, 2, 3, 4)
    qr_b = qr.reshape(b, n_q, bq, H, dr).transpose(1, 0, 2, 3, 4)
    ckv_b = ckv.reshape(b, n_kv, bkv, rkv).transpose(1, 0, 2, 3)
    kr_b = kr.reshape(b, n_kv, bkv, dr).transpose(1, 0, 2, 3)

    def q_step(_, qs):
        qnb, qrb, iq = qs
        # absorb: q_eff (B, bq, H, rkv) — computed per q block only
        q_eff = jnp.einsum("bihd,rhd->bihr", qnb.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        qpos = iq * bq + jnp.arange(bq)

        def kv_step(carry, kvs):
            m, l, acc = carry
            cb, krb, jk = kvs
            kpos = jk * bkv + jnp.arange(bkv)
            sc = jnp.einsum("bihr,bjr->bhij", q_eff, cb.astype(jnp.float32))
            sc += jnp.einsum("bihd,bjd->bhij", qrb.astype(jnp.float32),
                             krb.astype(jnp.float32))
            sc *= scale
            if causal:
                allow = kpos[None, :] <= qpos[:, None]
                sc = jnp.where(allow[None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            pr = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + pr.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhij,bjr->bhir", pr, cb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, H, bq), jnp.float32)
        a0 = jnp.zeros((b, H, bq, rkv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (ckv_b, kr_b, jnp.arange(n_kv)))
        ctx = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,H,bq,rkv)
        o = jnp.einsum("bhir,rhd->bihd", ctx, w_uv.astype(jnp.float32))
        return None, o.astype(qn.dtype)                     # (B,bq,H,dv)

    _, outs = jax.lax.scan(q_step, None, (qn_b, qr_b, jnp.arange(n_q)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, H, dv)
    return out[:, :s_orig]


def mla_decode(cfg: ModelConfig, ld: LayerDef, p: Params, x: jax.Array,
               cache: Cache, pos: jax.Array) -> Tuple[jax.Array, Cache]:
    b, d = x.shape
    H, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    h = _norm(cfg, p["ln"], x)
    qn, qr = _mla_q(cfg, p, h, pos)                # (B,H,dn/dr)
    ckv_new, kr_new = _mla_ckv(cfg, p, h, pos)     # (B,rkv) / (B,dr)
    bidx = jnp.arange(b)
    cq, cs = _kv_quant(cfg, ckv_new)
    cache = {
        "ckv_q": cache["ckv_q"].at[bidx, pos].set(cq),
        "ckv_s": cache["ckv_s"].at[bidx, pos].set(cs),
        "kr": cache["kr"].at[bidx, pos].set(kr_new),
    }
    ckv = _kv_dequant(cfg, cache["ckv_q"], cache["ckv_s"], x.dtype)
    ckv = constrain(ckv, ("batch", "kv_seq", None))
    kr = cache["kr"]
    w_uk, w_uv = _mla_absorbed_weights(cfg, p)

    q_eff = jnp.einsum("bhd,rhd->bhr", qn.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    sc = jnp.einsum("bhr,bjr->bhj", q_eff, ckv.astype(jnp.float32))
    sc += jnp.einsum("bhd,bjd->bhj", qr.astype(jnp.float32),
                     kr.astype(jnp.float32))
    sc *= (dn + dr) ** -0.5
    smax = ckv.shape[1]
    allow = jnp.arange(smax)[None, :] <= pos[:, None]
    sc = jnp.where(allow[:, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    ctx = jnp.einsum("bhj,bjr->bhr", pr, ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    return linear(o.reshape(b, H * dv).astype(x.dtype), p["wo"]), cache


# ---------------------------------------------------------------------------
# SSD mixer (mamba2 / jamba)
# ---------------------------------------------------------------------------

def _ssd_dims(cfg: ModelConfig):
    din = cfg.d_inner
    g, n, p_ = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    nh = din // p_
    return din, g, n, p_, nh


def _ssd_in_split(cfg: ModelConfig, zxbcdt: jax.Array):
    din, g, n, p_, nh = _ssd_dims(cfg)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * g * n]
    dt = zxbcdt[..., din + din + 2 * g * n:]
    return z, xbc, dt


def ssd_full(cfg: ModelConfig, ld: LayerDef, p: Params, x: jax.Array,
             positions, prefix_len, make_cache) -> Tuple[jax.Array, Optional[Cache]]:
    b, s, d = x.shape
    din, g, n, p_, nh = _ssd_dims(cfg)
    h = _norm(cfg, p["ln"], x)
    zxbcdt = linear(h, p["w_in"])
    z, xbc, dt = _ssd_in_split(cfg, zxbcdt)
    conv_out = jax.nn.silu(
        ssd_lib.causal_conv1d(xbc, p["conv_w"], p["conv_b"]))
    xs = conv_out[..., :din].reshape(b, s, g, nh // g, p_)
    b_in = conv_out[..., din:din + g * n].reshape(b, s, g, n)
    c_in = conv_out[..., din + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt + p["dt_bias"]).reshape(b, s, g, nh // g)
    xs = constrain(xs, ("batch", "seq", None, "heads", None))
    y, h_fin = ssd_lib.ssd_chunked(xs, dt, p["a_log"], b_in, c_in,
                                   p["d_skip"], cfg.ssm_chunk)
    y = y.reshape(b, s, din)
    y = ssd_lib.gated_rms_norm(y, z, p["gn"], cfg.rms_eps)
    out = linear(y, p["w_out"])

    cache = None
    if make_cache is not None:
        w = cfg.conv_width
        cache = {"h": h_fin, "conv": xbc[:, s - (w - 1):s, :]}
    return out, cache


def ssd_decode(cfg: ModelConfig, ld: LayerDef, p: Params, x: jax.Array,
               cache: Cache, pos: jax.Array) -> Tuple[jax.Array, Cache]:
    b, d = x.shape
    din, g, n, p_, nh = _ssd_dims(cfg)
    h = _norm(cfg, p["ln"], x)
    zxbcdt = linear(h, p["w_in"])
    z, xbc, dt = _ssd_in_split(cfg, zxbcdt)
    conv_new, conv_out = ssd_lib.conv1d_step(
        cache["conv"], xbc, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :din].reshape(b, g, nh // g, p_)
    b_in = conv_out[..., din:din + g * n].reshape(b, g, n)
    c_in = conv_out[..., din + g * n:].reshape(b, g, n)
    dt = jax.nn.softplus(dt + p["dt_bias"]).reshape(b, g, nh // g)
    y, h_new = ssd_lib.ssd_decode_step(cache["h"], xs, dt, p["a_log"],
                                       b_in, c_in, p["d_skip"])
    y = y.reshape(b, din)
    y = ssd_lib.gated_rms_norm(y, z, p["gn"], cfg.rms_eps)
    return linear(y, p["w_out"]), {"h": h_new, "conv": conv_new}


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------

def dense_ffn(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = _norm(cfg, p["ln2"], x)
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True)
        g = act(linear(h, p["w_gate"]))
        u = linear(h, p["w_up"])
        hh = constrain(g * u, ("batch", "seq", "mlp"))
        return linear(hh, p["w_down"], tp="row")
    hh = jax.nn.gelu(linear(h, p["w_fc"], p.get("b_fc")), approximate=True)
    hh = constrain(hh, ("batch", "seq", "mlp"))
    return linear(hh, p["w_proj"], p.get("b_proj"), tp="row")


def moe_ffn(cfg: ModelConfig, p: Params,
            x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, load-balance aux loss).

    Under a tensor-parallel trace whose BATCH is sharded over a data axis
    (the decode/verify serving steps), the flat token batch is
    all-gathered before routing and the local rows sliced back out after
    the combine: expert capacity and within-expert ranking are functions
    of the whole batch, so routing on local shards alone would keep/drop
    different assignments than the single-device step. The gathered rows
    arrive in global slot order (shards own contiguous slot ranges), so
    dispatch, capacity and combine are bit-identical to the unsharded
    batch; the expert FFNs themselves are sharded on their hidden dim
    (one int32 psum per down-projection — see ``distributed/tp.py``).
    """
    h = _norm(cfg, p["ln2"], x)
    shp = h.shape
    flat = h.reshape(-1, shp[-1])
    ctx = tp_ctx()
    gathered = ctx is not None and ctx.batch_axis is not None
    if gathered:
        t_local = flat.shape[0]
        flat = jax.lax.all_gather(flat, ctx.batch_axis, axis=0, tiled=True)
    mp = p["moe"]
    y = moe_lib.moe_ffn_dist(
        flat, mp["w_router"], mp["w_gate"], mp["w_up"], mp["w_down"],
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        router_type=cfg.router_type)
    if cfg.n_shared_experts:
        y = y + moe_lib.shared_expert_ffn(
            flat, mp["w_shared_gate"], mp["w_shared_up"],
            mp["w_shared_down"])
    aux = moe_lib.load_balance_loss(flat, mp["w_router"], cfg.top_k)
    if gathered:
        start = jax.lax.axis_index(ctx.batch_axis) * t_local
        y = jax.lax.dynamic_slice_in_dim(y, start, t_local, axis=0)
    return y.reshape(shp), aux


# ---------------------------------------------------------------------------
# layer / stage application
# ---------------------------------------------------------------------------

_MIXER_FULL = {"attn": attn_full, "mla": mla_full, "ssd": ssd_full}
_MIXER_DEC = {"attn": attn_decode, "mla": mla_decode, "ssd": ssd_decode}


def _apply_layer_full(cfg, ld: LayerDef, p: Params, x, positions,
                      prefix_len, make_cache):
    y, cache = _MIXER_FULL[ld.mixer](cfg, ld, p, x, positions, prefix_len,
                                     make_cache)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ld.ffn == "dense":
        x = x + dense_ffn(cfg, p, x)
    elif ld.ffn == "moe":
        y, aux = moe_ffn(cfg, p, x)
        x = x + y
    x = constrain(x, ("batch", "seq", "embed"))
    return x, cache, aux


def _apply_ffn_decode(cfg, ld: LayerDef, p: Params, x):
    """Single-token FFN residual, shared by every decode cache layout."""
    if ld.ffn == "dense":
        return x + dense_ffn(cfg, p, x[:, None, :])[:, 0]
    if ld.ffn == "moe":
        return x + moe_ffn(cfg, p, x[:, None, :])[0][:, 0]
    return x


def _apply_layer_decode(cfg, ld: LayerDef, p: Params, x, cache, pos):
    y, cache = _MIXER_DEC[ld.mixer](cfg, ld, p, x, cache, pos)
    return _apply_ffn_decode(cfg, ld, p, x + y), cache


def _stage_scan_full(cfg, stage: Stage, sparams, x, positions, prefix_len,
                     make_cache, remat: bool):
    """Returns (x, caches-or-None, total aux loss)."""

    def body(carry, pslice):
        h, aux = carry
        caches = {}
        for pi, ld in enumerate(stage.period):
            h, c, a = _apply_layer_full(cfg, ld, pslice[f"p{pi}"], h,
                                        positions, prefix_len, make_cache)
            aux = aux + a
            if make_cache is not None:
                caches[f"p{pi}"] = c
        return (h, aux), (caches if make_cache is not None else None)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    sparams)
    return x, caches, aux


def _stage_scan_decode(cfg, stage: Stage, sparams, scache, x, pos):
    def body(carry, inp):
        h = carry
        pslice, cslice = inp
        new_c = {}
        for pi, ld in enumerate(stage.period):
            h, c = _apply_layer_decode(cfg, ld, pslice[f"p{pi}"], h,
                                       cslice[f"p{pi}"], pos)
            new_c[f"p{pi}"] = c
        return h, new_c

    x, new_cache = jax.lax.scan(body, x, (sparams, scache))
    return x, new_cache


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params: Params,
                 batch: Dict[str, jax.Array]):
    """Returns (x (B,S,D), positions (S,), prefix_len)."""
    dt = cfg.cdtype
    prefix_len = 0
    if cfg.family == "encoder":
        x = batch["frames"].astype(dt)        # stub frontend: precomputed
    elif cfg.family == "vlm":
        patches = batch["patches"].astype(dt)  # stub SigLIP: precomputed
        tok = embed(batch["tokens"], params["embed"]["table"]).astype(dt)
        x = jnp.concatenate([patches, tok], axis=1)
        prefix_len = patches.shape[1]
    else:
        x = embed(batch["tokens"], params["embed"]["table"]).astype(dt)
    if cfg.family == "vlm" or cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)   # gemma embed scaling
    positions = jnp.arange(x.shape[1])
    return x, positions, prefix_len


def head_logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        # tied head: the embedding table stays replicated under TP (token
        # lookup needs the full vocab), so logits are already complete
        return linear(x, params["embed"]["table"].T)
    logits = linear(x, params["lm_head"])
    ctx = tp_ctx()
    if ctx is not None and logits.shape[-1] != cfg.vocab:
        # column-parallel head: gather the vocab shards back into the
        # full distribution (exact concatenation, shard order = axis
        # order) — sampling policy lives host-side in the engine
        logits = jax.lax.all_gather(logits, ctx.axis, axis=logits.ndim - 1,
                                    tiled=True)
    return logits


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, remat: bool = False, with_aux: bool = False):
    """Full-sequence forward -> logits (B, S, V) [, aux loss]."""
    x, aux = forward_hidden(cfg, params, batch, remat=remat, with_aux=True)
    logits = head_logits(cfg, params, x)
    return (logits, aux) if with_aux else logits


def forward_hidden(cfg: ModelConfig, params: Params, batch, *,
                   remat: bool = False, with_aux: bool = False):
    """Forward without the head (final pre-norm hidden states)."""
    x, positions, prefix_len = embed_inputs(cfg, params, batch)
    x = constrain(x, ("batch", "seq", "embed"))
    total_aux = jnp.zeros((), jnp.float32)
    for si, stage in enumerate(build_stages(cfg)):
        x, _, aux = _stage_scan_full(cfg, stage, params["stages"][f"s{si}"],
                                     x, positions, prefix_len, None, remat)
        total_aux = total_aux + aux
    return (x, total_aux) if with_aux else x


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, max_len: int) -> Tuple[jax.Array, Cache]:
    """Prefill: logits of the LAST position + initialized caches."""
    x, positions, prefix_len = embed_inputs(cfg, params, batch)
    x = constrain(x, ("batch", "seq", "embed"))
    cache: Cache = {"stages": {}}
    for si, stage in enumerate(build_stages(cfg)):
        x, c, _ = _stage_scan_full(cfg, stage, params["stages"][f"s{si}"], x,
                                   positions, prefix_len, max_len, False)
        cache["stages"][f"s{si}"] = c
    logits = head_logits(cfg, params, x[:, -1:, :])[:, 0]
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                token: jax.Array, pos: jax.Array,
                embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Cache]:
    """One decode step. token (B,) int32, pos (B,) int32 -> logits (B, V)."""
    dt = cfg.cdtype
    if cfg.family == "encoder":
        raise ValueError("encoder-only model has no decode step")
    if embeds is not None:
        x = embeds.astype(dt)
    else:
        x = embed(token, params["embed"]["table"]).astype(dt)
    if cfg.family == "vlm" or cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = constrain(x, ("batch", "embed"))
    new_cache: Cache = {"stages": {}}
    for si, stage in enumerate(build_stages(cfg)):
        x, nc = _stage_scan_decode(
            cfg, stage, params["stages"][f"s{si}"],
            cache["stages"][f"s{si}"], x, pos)
        new_cache["stages"][f"s{si}"] = nc
    logits = head_logits(cfg, params, x[:, None, :])[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# paged-cache serving entry points (serving/engine.py)
#
# The contiguous decode path above owns a (B, Smax, ...) cache per layer;
# the serving engine instead owns a shared page pool per layer —
# (n_pages, page_size, KVH, ...) in the same packed-int4 wire format —
# and per-sequence block tables mapping sequence-order page steps to
# physical pages. KV is quantized on write and never dequantized in HBM
# on the decode hot path (kernels/kv_attention.py walks the table).
# ---------------------------------------------------------------------------


def check_paged_support(cfg: ModelConfig) -> None:
    """Raise unless every layer fits the paged attention serving path."""
    if cfg.family in ("encoder", "vlm"):
        raise NotImplementedError(
            f"paged serving needs a token-only decoder, got {cfg.family}")
    if cfg.kv_bits != 4 or cfg.hd % 2:
        raise NotImplementedError(
            f"paged pool stores packed int4 KV: kv_bits=4, even head_dim "
            f"required (got kv_bits={cfg.kv_bits}, hd={cfg.hd})")
    for stage in build_stages(cfg):
        for ld in stage.period:
            if ld.mixer != "attn" or ld.window:
                raise NotImplementedError(
                    f"paged serving supports full-attention GQA layers only "
                    f"(got mixer={ld.mixer!r}, window={ld.window})")


def _act_subprecision_sparsity(x: jax.Array) -> jax.Array:
    """Per-row MSB4 sparsity of the int8-quantized activations (B,)."""
    from repro.core.quantize import quantize_activations
    from repro.core.sparqle import subprecision_sparsity
    q = quantize_activations(x, bits=8, per_token=True).q
    return subprecision_sparsity(q, axis=-1)


def attn_decode_paged(cfg: ModelConfig, ld: LayerDef, p: Params,
                      x: jax.Array, pool: Cache, block_tables: jax.Array,
                      pos: jax.Array,
                      tier_tables: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Cache]:
    """One-token attention against the paged pool. x: (B, D).

    Writes the new token's quantized K/V into its page slot, then attends
    through the block table with the paged Pallas kernel (the pool stays
    in packed-int4 wire format end to end). With ``tier_tables`` (B, Pmax)
    the mixed-tier kernel reads each page from the slab its tier id names
    (the KV2 precision ladder — serving/kv_pool.py); the write still lands
    in the KV4 slab, since the engine promotes any page before it is
    written (the frontier page is always tier 0).
    """
    from repro.kernels.kv_attention import (kv4_paged_decode_attention,
                                            kv_tiered_paged_decode_attention)
    b, d = x.shape
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    theta = ld.rope_theta or cfg.rope_theta
    h = _norm(cfg, p["ln"], x)
    q, k_new, v_new = _attn_qkv(cfg, p, h, pos, theta)
    kq, ks = _kv_quant(cfg, k_new)
    vq, vs = _kv_quant(cfg, v_new)
    ps = pool["k_q"].shape[1]
    n_steps = block_tables.shape[1]
    bidx = jnp.arange(b)
    page = block_tables[bidx, jnp.clip(pos // ps, 0, n_steps - 1)]
    if tier_tables is not None:
        # a demoted page id indexes the KV2 slab — never scatter there
        page = jnp.where(
            tier_tables[bidx, jnp.clip(pos // ps, 0, n_steps - 1)] == 0,
            page, 0)
    off = pos % ps
    pool = {
        **pool,                       # KV2 slab (if any) passes through
        "k_q": pool["k_q"].at[page, off].set(kq),
        "k_s": pool["k_s"].at[page, off].set(ks),
        "v_q": pool["v_q"].at[page, off].set(vq),
        "v_s": pool["v_s"].at[page, off].set(vs),
    }
    if tier_tables is None:
        o = kv4_paged_decode_attention(
            q.reshape(b, kvh, g, cfg.hd), pool["k_q"], pool["k_s"],
            pool["v_q"], pool["v_s"], block_tables, pos)
    else:
        o = kv_tiered_paged_decode_attention(
            q.reshape(b, kvh, g, cfg.hd), pool["k_q"], pool["k_s"],
            pool["v_q"], pool["v_s"], pool["k2_q"], pool["k2_s"],
            pool["v2_q"], pool["v2_s"], block_tables, tier_tables, pos)
    o = o.reshape(b, cfg.n_heads * cfg.hd)
    return linear(o, p["wo"], p.get("bo"), tp="row"), pool


def _apply_layer_decode_paged(cfg, ld: LayerDef, p: Params, x, pool,
                              block_tables, pos, tier_tables=None):
    y, pool = attn_decode_paged(cfg, ld, p, x, pool, block_tables, pos,
                                tier_tables)
    return _apply_ffn_decode(cfg, ld, p, x + y), pool


def decode_step_paged(cfg: ModelConfig, params: Params, pool: Cache,
                      token: jax.Array, pos: jax.Array,
                      block_tables: jax.Array, *,
                      tier_tables: Optional[jax.Array] = None,
                      msb_skip: bool = False,
                      with_telemetry: bool = True
                      ) -> Tuple[jax.Array, Cache, Dict[str, jax.Array]]:
    """One continuous-batching decode step over the paged pool.

    token/pos (B,) int32, block_tables (B, Pmax) int32. Inactive slots
    should carry an all-zero block-table row: their KV writes land in the
    reserved null page 0 and their outputs are discarded by the engine.
    Returns (logits (B, V), new pool, telemetry dict):

      * ``sparsity``          (B,)   — final-hidden MSB4 sparsity,
      * ``layer_sparsity``    (L, B) — MSB4 sparsity of the hidden
        (residual) stream entering each layer,
      * ``layer_wire_bytes``  (L, B) — MEASURED packed-wire bytes of that
        inter-layer stream (``core/packing.py`` layout; see
        ``layers.act_wire_telemetry`` for what this does and does not
        include),
      * ``layer_dense_bytes`` (L, B) — dense int8 baseline bytes.

    ``msb_skip`` traces every sparqle projection in LSB4-only draft mode
    (the 1-compute-round proposer of self-speculative decoding; see
    ``serving/spec_decode.py``) — the K/V written to the pool are then
    the draft's approximations, which the verification step overwrites.
    ``with_telemetry=False`` drops the wire accounting from the traced
    program (the draft hot path) and returns an empty telemetry dict.
    ``tier_tables`` (B, Pmax) arms the KV2 precision-ladder read path
    (see :func:`attn_decode_paged`); None keeps the KV4-only program.
    """
    with msb_skip_scope(msb_skip):
        return _decode_step_paged_body(cfg, params, pool, token, pos,
                                       block_tables, with_telemetry,
                                       tier_tables)


def _decode_step_paged_body(cfg, params, pool, token, pos, block_tables,
                            with_telemetry, tier_tables=None):
    dt = cfg.cdtype
    x = embed(token, params["embed"]["table"]).astype(dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = constrain(x, ("batch", "embed"))
    new_pool: Cache = {"stages": {}}
    layer_tels = []
    for si, stage in enumerate(build_stages(cfg)):
        def body(h, inp, stage=stage):
            pslice, cslice = inp
            tels = []
            new_c = {}
            for pi, ld in enumerate(stage.period):
                if with_telemetry:
                    tels.append(act_wire_telemetry(h))  # one per SUB-layer
                h, c = _apply_layer_decode_paged(
                    cfg, ld, pslice[f"p{pi}"], h, cslice[f"p{pi}"],
                    block_tables, pos, tier_tables)
                new_c[f"p{pi}"] = c
            tel = stack_sublayer_telemetry(tels) if with_telemetry else {}
            return h, (new_c, tel)

        x, (nc, tel) = jax.lax.scan(body, x, (params["stages"][f"s{si}"],
                                              pool["stages"][f"s{si}"]))
        new_pool["stages"][f"s{si}"] = nc
        # scan stacks to (repeat, period, B): flatten to per-layer (L_s, B)
        layer_tels.append({k: v.reshape(-1, *v.shape[2:])
                           for k, v in tel.items()})
    telemetry: Dict[str, jax.Array] = {}
    if with_telemetry:
        telemetry["sparsity"] = _act_subprecision_sparsity(x)
        for key in ("sparsity", "wire_bytes", "dense_bytes"):
            telemetry[f"layer_{key}"] = jnp.concatenate(
                [t[key] for t in layer_tels], axis=0)
    logits = head_logits(cfg, params, x[:, None, :])[:, 0]
    return logits, new_pool, telemetry


def attn_verify_paged(cfg: ModelConfig, ld: LayerDef, p: Params,
                      x: jax.Array, pool: Cache, block_tables: jax.Array,
                      pos: jax.Array) -> Tuple[jax.Array, Cache]:
    """Draft-window attention for speculative verification. x: (B, T, D).

    Window token ``t`` of sequence ``b`` sits at absolute position
    ``pos[b] + t``. All T tokens' K/V are quantized and scattered into
    their page slots FIRST (overwriting whatever the LSB-only draft pass
    left there), then the whole window attends through the block table in
    one multi-token paged kernel call — each token causally masked to its
    own position, so it sees the window's just-written full-precision K/V
    but never its own future.
    """
    from repro.kernels.kv_attention import kv4_paged_verify_attention
    b, t, d = x.shape
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    theta = ld.rope_theta or cfg.rope_theta
    h = _norm(cfg, p["ln"], x)
    positions = pos[:, None] + jnp.arange(t)[None, :]       # (B, T)
    q, k_new, v_new = _attn_qkv(cfg, p, h, positions, theta)
    kq, ks = _kv_quant(cfg, k_new)
    vq, vs = _kv_quant(cfg, v_new)
    ps = pool["k_q"].shape[1]
    n_steps = block_tables.shape[1]
    step = jnp.clip(positions // ps, 0, n_steps - 1)
    page = jnp.take_along_axis(block_tables, step, axis=1)  # (B, T)
    off = positions % ps
    pool = {
        **pool,                       # KV2 slab (if any) passes through
        "k_q": pool["k_q"].at[page, off].set(kq),
        "k_s": pool["k_s"].at[page, off].set(ks),
        "v_q": pool["v_q"].at[page, off].set(vq),
        "v_s": pool["v_s"].at[page, off].set(vs),
    }
    o = kv4_paged_verify_attention(
        q.reshape(b, t, kvh, g, cfg.hd), pool["k_q"], pool["k_s"],
        pool["v_q"], pool["v_s"], block_tables, pos)
    o = o.reshape(b, t, cfg.n_heads * cfg.hd)
    return linear(o, p["wo"], p.get("bo"), tp="row"), pool


def verify_window_paged(cfg: ModelConfig, params: Params, pool: Cache,
                        tokens: jax.Array, pos: jax.Array,
                        block_tables: jax.Array
                        ) -> Tuple[jax.Array, Cache, Dict[str, jax.Array]]:
    """Score a whole draft window in ONE full-precision batched step.

    tokens (B, T) int32 — window token 0 is the last accepted token,
    tokens 1..T-1 the draft proposals; pos (B,) int32 — absolute position
    of tokens[:, 0]; block_tables (B, Pmax) int32. Returns
    (logits (B, T, V), new pool, telemetry):

      * ``logits[:, t]`` is the full-precision next-token distribution
        after window token t — exactly what a sequential decode at
        ``pos + t`` would produce (the attention kernel is bit-exact
        against that loop; see ``kernels/kv_attention.py``);
      * the pool comes back with full-precision K/V written at every
        window position, which is what makes greedy speculative decoding
        byte-identical to the non-speculative engine: rejected tail
        positions hold stale K/V but sit beyond the accepted position,
        masked until overwritten;
      * telemetry: ``sparsity`` (B,) mean final-hidden MSB4 sparsity over
        the window; ``layer_sparsity`` (L, B) mean over window tokens;
        ``layer_wire_bytes`` / ``layer_dense_bytes`` (L, B) measured
        packed-wire vs dense int8 bytes summed over the window's
        inter-layer hidden stream.
    """
    dt = cfg.cdtype
    x = embed(tokens, params["embed"]["table"]).astype(dt)   # (B, T, D)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = constrain(x, ("batch", "seq", "embed"))
    new_pool: Cache = {"stages": {}}
    layer_tels = []
    for si, stage in enumerate(build_stages(cfg)):
        def body(h, inp, stage=stage):
            pslice, cslice = inp
            tels = []
            new_c = {}
            for pi, ld in enumerate(stage.period):
                tels.append(act_wire_telemetry(h))   # one per SUB-layer
                y, c = attn_verify_paged(
                    cfg, ld, pslice[f"p{pi}"], h, cslice[f"p{pi}"],
                    block_tables, pos)
                h = h + y
                if ld.ffn == "dense":
                    h = h + dense_ffn(cfg, pslice[f"p{pi}"], h)
                elif ld.ffn == "moe":
                    # one routed-MoE call PER WINDOW POSITION: expert
                    # capacity is a function of the flat token count
                    # (t * top_k * cf // E), so batching all B*T window
                    # tokens into one dispatch would drop different
                    # assignments than the B-token sequential decode
                    # steps this function must be bit-exact against
                    h = h + jnp.concatenate(
                        [moe_ffn(cfg, pslice[f"p{pi}"],
                                 h[:, t:t + 1])[0]
                         for t in range(h.shape[1])], axis=1)
                new_c[f"p{pi}"] = c
            return h, (new_c, stack_sublayer_telemetry(tels))

        x, (nc, tel) = jax.lax.scan(body, x, (params["stages"][f"s{si}"],
                                              pool["stages"][f"s{si}"]))
        new_pool["stages"][f"s{si}"] = nc
        # scan stacks to (repeat, period, B, T): flatten to (L_s, B, T)
        layer_tels.append({k: v.reshape(-1, *v.shape[2:])
                           for k, v in tel.items()})
    cat = lambda key: jnp.concatenate(  # noqa: E731
        [t[key] for t in layer_tels], axis=0)
    telemetry = {
        "sparsity": _act_subprecision_sparsity(x).mean(axis=-1),
        "layer_sparsity": cat("sparsity").mean(axis=-1),
        "layer_wire_bytes": cat("wire_bytes").sum(axis=-1),
        "layer_dense_bytes": cat("dense_bytes").sum(axis=-1),
    }
    logits = head_logits(cfg, params, x)                     # (B, T, V)
    return logits, new_pool, telemetry


def _attn_prefill_chunk_paged(cfg: ModelConfig, ld: LayerDef, p: Params,
                              x: jax.Array, pool: Cache,
                              block_table: jax.Array, start: jax.Array,
                              valid: jax.Array) -> Tuple[jax.Array, Cache]:
    """Chunked-prefill attention for ONE sequence. x: (1, C, D).

    The chunk's K/V are quantized and scattered into the sequence's pages;
    queries attend to the dequantized pool for positions < start (the wire
    format is the source of truth for past context) and to the float
    chunk K/V for the chunk itself — so a single-chunk prefill is exactly
    the legacy float prefill attention.
    """
    _, c, _ = x.shape
    kvh, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    theta = ld.rope_theta or cfg.rope_theta
    h = _norm(cfg, p["ln"], x)
    positions = start + jnp.arange(c)
    q, k, v = _attn_qkv(cfg, p, h, positions, theta)

    ps = pool["k_q"].shape[1]
    n_steps = block_table.shape[1]
    kq, ks = _kv_quant(cfg, k)
    vq, vs = _kv_quant(cfg, v)
    valid_tok = jnp.arange(c) < valid
    page = jnp.where(valid_tok,
                     block_table[0, jnp.clip(positions // ps, 0,
                                             n_steps - 1)], 0)
    off = positions % ps
    pool = {
        **pool,                       # KV2 slab (if any) passes through
        "k_q": pool["k_q"].at[page, off].set(kq[0]),
        "k_s": pool["k_s"].at[page, off].set(ks[0]),
        "v_q": pool["v_q"].at[page, off].set(vq[0]),
        "v_s": pool["v_s"].at[page, off].set(vs[0]),
    }

    # context = dequantized pool pages [0, start) ++ float chunk K/V
    kp = pool["k_q"][block_table[0]].reshape(n_steps * ps, kvh, hd // 2)
    ksp = pool["k_s"][block_table[0]].reshape(n_steps * ps, kvh)
    vp = pool["v_q"][block_table[0]].reshape(n_steps * ps, kvh, hd // 2)
    vsp = pool["v_s"][block_table[0]].reshape(n_steps * ps, kvh)
    k_past = _kv_dequant(cfg, kp, ksp, jnp.float32)[None]
    v_past = _kv_dequant(cfg, vp, vsp, jnp.float32)[None]
    k_cat = jnp.concatenate([k_past, k.astype(jnp.float32)], 1)
    v_cat = jnp.concatenate([v_past, v.astype(jnp.float32)], 1)

    lmax = n_steps * ps
    i = jnp.arange(c)[:, None]
    j = jnp.arange(lmax + c)[None, :]
    allow = jnp.where(j < lmax, j < start, (j - lmax) <= i)
    qg = q.reshape(1, c, kvh, g, hd).astype(jnp.float32)
    s = jnp.einsum("bikgd,bjkd->bkgij", qg, k_cat) * hd ** -0.5
    s = jnp.where(allow[None, None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", pr, v_cat)
    o = o.reshape(1, c, cfg.n_heads * hd).astype(x.dtype)
    return linear(o, p["wo"], p.get("bo"), tp="row"), pool


def prefill_chunk_paged(cfg: ModelConfig, params: Params, pool: Cache,
                        tokens: jax.Array, start: jax.Array,
                        valid: jax.Array, block_table: jax.Array
                        ) -> Tuple[jax.Array, Cache, Dict[str, jax.Array]]:
    """Prefill one chunk of ONE sequence into the paged pool.

    tokens (1, C) int32 (tail-padded; ``valid`` counts real tokens),
    start — absolute position of tokens[0, 0], block_table (1, Pmax).
    Returns (logits (1, V) of the last valid position, new pool, telemetry
    dict): ``sparsity`` — mean MSB4 sparsity of the chunk's final hidden
    activations over valid tokens; ``layer_sparsity`` (L,) mean over the
    hidden stream entering each layer; ``layer_wire_bytes`` /
    ``layer_dense_bytes`` (L,) — measured packed-wire vs dense int8 bytes
    of the chunk's valid tokens on that inter-layer stream
    (``layers.act_wire_telemetry``).
    """
    dt = cfg.cdtype
    x = embed(tokens, params["embed"]["table"]).astype(dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = constrain(x, ("batch", "seq", "embed"))
    new_pool: Cache = {"stages": {}}
    layer_tels = []
    for si, stage in enumerate(build_stages(cfg)):
        def body(h, inp, stage=stage):
            pslice, cslice = inp
            tels = []
            new_c = {}
            for pi, ld in enumerate(stage.period):
                tels.append(act_wire_telemetry(h))   # one per SUB-layer
                y, c = _attn_prefill_chunk_paged(
                    cfg, ld, pslice[f"p{pi}"], h, cslice[f"p{pi}"],
                    block_table, start, valid)
                h = h + y
                if ld.ffn == "dense":
                    h = h + dense_ffn(cfg, pslice[f"p{pi}"], h)
                elif ld.ffn == "moe":
                    h = h + moe_ffn(cfg, pslice[f"p{pi}"], h)[0]
                new_c[f"p{pi}"] = c
            return h, (new_c, stack_sublayer_telemetry(tels))

        x, (nc, tel) = jax.lax.scan(body, x, (params["stages"][f"s{si}"],
                                              pool["stages"][f"s{si}"]))
        new_pool["stages"][f"s{si}"] = nc
        # scan stacks to (repeat, period, 1, C): flatten to (L_s, 1, C)
        layer_tels.append({k: v.reshape(-1, *v.shape[2:])
                           for k, v in tel.items()})
    last = jnp.maximum(valid - 1, 0)
    valid_tok = (jnp.arange(tokens.shape[1]) < valid).astype(jnp.float32)
    n_valid = jnp.maximum(jnp.sum(valid_tok), 1.0)
    sp_tok = _act_subprecision_sparsity(x[0])
    # per-layer stats over the chunk's VALID tokens only
    cat = lambda key: jnp.concatenate(  # noqa: E731
        [t[key][:, 0, :] for t in layer_tels], axis=0)
    telemetry = {
        "sparsity": jnp.sum(sp_tok * valid_tok) / n_valid,
        "layer_sparsity": jnp.sum(cat("sparsity") * valid_tok, -1) / n_valid,
        "layer_wire_bytes": jnp.sum(cat("wire_bytes") * valid_tok, -1),
        "layer_dense_bytes": jnp.sum(cat("dense_bytes") * valid_tok, -1),
    }
    x_last = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
    logits = head_logits(cfg, params, x_last)[:, 0]
    return logits, new_pool, telemetry


# ---------------------------------------------------------------------------
# MTP head (deepseek-v3 auxiliary multi-token prediction)
# ---------------------------------------------------------------------------

def mtp_logits(cfg: ModelConfig, params: Params, hidden: jax.Array,
               batch: Dict[str, jax.Array]) -> jax.Array:
    """Predict token t+2 from trunk hidden t and embedding of token t+1.

    ``hidden`` is the trunk's final (pre-norm) hidden states (B, S, D).
    Returns logits (B, S-1, V) aligned so position i predicts tokens[i+2].
    """
    mp = params["mtp"]
    tok = batch["tokens"]
    h = _norm(cfg, mp["norm_h"], hidden[:, :-1, :])
    e = embed(tok[:, 1:], params["embed"]["table"]).astype(h.dtype)
    e = _norm(cfg, mp["norm_e"], e)
    x = linear(jnp.concatenate([h, e], axis=-1), mp["proj"])
    positions = jnp.arange(x.shape[1])
    ld = LayerDef("mla" if cfg.use_mla else "attn", "dense")
    stage = Stage([ld], cfg.mtp_depth)
    x, _, _ = _stage_scan_full(cfg, stage, {"p0": mp["block"]}, x, positions,
                               0, None, False)
    return head_logits(cfg, params, x)
