"""Per-architecture parameter schema construction.

Builds the nested ParamSpec tree for any :class:`ModelConfig`, organized by
the stage plan (``stages.build_stages``): every leaf under ``stages/s<i>``
carries a leading ``repeat`` (scan) dimension. Mixer/FFN projection leaves
use the canonical names :mod:`repro.core.qlinear` recognizes, so the same
tree quantizes into SPARQLe served form with zero model-code changes.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.schema import ParamSpec, Schema
from repro.models.stages import LayerDef, build_stages


def _norm_schema(cfg: ModelConfig, dim: int) -> Schema:
    s: Schema = {"gamma": ParamSpec((dim,), (None,), init="zeros")}
    if cfg.norm_type == "layer":
        s = {"gamma": ParamSpec((dim,), (None,), init="ones"),
             "beta": ParamSpec((dim,), (None,), init="zeros")}
    return s


def _attn_schema(cfg: ModelConfig) -> Schema:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s: Schema = {
        "ln": _norm_schema(cfg, d),
        "wq": ParamSpec((d, h * hd), ("embed", "heads_flat")),
        "wk": ParamSpec((d, kvh * hd), ("embed", "heads_flat")),
        "wv": ParamSpec((d, kvh * hd), ("embed", "heads_flat")),
        "wo": ParamSpec((h * hd, d), ("heads_flat", "embed")),
    }
    if cfg.use_bias:
        s.update({
            "bq": ParamSpec((h * hd,), (None,), init="zeros"),
            "bk": ParamSpec((kvh * hd,), (None,), init="zeros"),
            "bv": ParamSpec((kvh * hd,), (None,), init="zeros"),
            "bo": ParamSpec((d,), (None,), init="zeros"),
        })
    if cfg.use_qk_norm:
        s["q_norm"] = ParamSpec((hd,), (None,), init="zeros")
        s["k_norm"] = ParamSpec((hd,), (None,), init="zeros")
    return s


def _mla_schema(cfg: ModelConfig) -> Schema:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "ln": _norm_schema(cfg, d),
        "wq_a": ParamSpec((d, rq), ("embed", None)),
        "q_norm": ParamSpec((rq,), (None,), init="zeros"),
        "wq_b": ParamSpec((rq, h * (dn + dr)), (None, "heads_flat")),
        "wkv_a": ParamSpec((d, rkv + dr), ("embed", None)),
        "kv_norm": ParamSpec((rkv,), (None,), init="zeros"),
        "wkv_b": ParamSpec((rkv, h * (dn + dv)), (None, "heads_flat")),
        "wo": ParamSpec((h * dv, d), ("heads_flat", "embed")),
    }


def _ssd_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    din = cfg.d_inner
    g, n, p_ = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    nh = din // p_
    conv_ch = din + 2 * g * n
    return {
        "ln": _norm_schema(cfg, d),
        "w_in": ParamSpec((d, 2 * din + 2 * g * n + nh), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.conv_width, conv_ch), (None, "conv")),
        "conv_b": ParamSpec((conv_ch,), ("conv",), init="zeros"),
        "a_log": ParamSpec((g, nh // g), (None, None), init="zeros"),
        "d_skip": ParamSpec((g, nh // g), (None, None), init="ones"),
        "dt_bias": ParamSpec((nh,), (None,), init="zeros"),
        "gn": ParamSpec((din,), (None,), init="zeros"),
        "w_out": ParamSpec((din, d), ("mlp", "embed")),
    }


def _dense_ffn_schema(cfg: ModelConfig) -> Schema:
    d, f = cfg.d_model, cfg.d_ff
    s: Schema = {"ln2": _norm_schema(cfg, d)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        s.update({
            "w_gate": ParamSpec((d, f), ("embed", "mlp")),
            "w_up": ParamSpec((d, f), ("embed", "mlp")),
            "w_down": ParamSpec((f, d), ("mlp", "embed")),
        })
    else:  # plain gelu MLP (starcoder2, hubert)
        s.update({
            "w_fc": ParamSpec((d, f), ("embed", "mlp")),
            "w_proj": ParamSpec((f, d), ("mlp", "embed")),
        })
        if cfg.use_bias:
            s["b_fc"] = ParamSpec((f,), ("mlp",), init="zeros")
            s["b_proj"] = ParamSpec((d,), (None,), init="zeros")
    return s


def _moe_ffn_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    moe: Schema = {
        "w_router": ParamSpec((d, e), ("embed", None)),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", None)),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", None)),
        "w_down": ParamSpec((e, f, d), ("experts", None, "embed")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        moe.update({
            "w_shared_gate": ParamSpec((d, fs), ("embed", "mlp")),
            "w_shared_up": ParamSpec((d, fs), ("embed", "mlp")),
            "w_shared_down": ParamSpec((fs, d), ("mlp", "embed")),
        })
    return {"ln2": _norm_schema(cfg, d), "moe": moe}


def layer_schema(cfg: ModelConfig, ld: LayerDef) -> Schema:
    s: Schema = {}
    if ld.mixer == "attn":
        s.update(_attn_schema(cfg))
    elif ld.mixer == "mla":
        s.update(_mla_schema(cfg))
    elif ld.mixer == "ssd":
        s.update(_ssd_schema(cfg))
    else:
        raise ValueError(ld.mixer)
    if ld.ffn == "dense":
        s.update(_dense_ffn_schema(cfg))
    elif ld.ffn == "moe":
        s.update(_moe_ffn_schema(cfg))
    return s


def _stack(schema: Schema, repeat: int) -> Schema:
    """Prepend the scan ('layers') dim to every spec in the subtree."""
    out: Schema = {}
    for k, v in schema.items():
        if isinstance(v, dict):
            out[k] = _stack(v, repeat)
        else:
            out[k] = ParamSpec((repeat,) + v.shape, ("layers",) + v.axes,
                               v.dtype, v.init, v.scale)
    return out


def build_schema(cfg: ModelConfig) -> Schema:
    d, v = cfg.d_model, cfg.vocab
    schema: Schema = {
        "embed": {"table": ParamSpec((v, d), ("vocab", "embed"),
                                     init="embed", scale=0.02)},
        "stages": {},
        "final_norm": _norm_schema(cfg, d),
    }
    for si, stage in enumerate(build_stages(cfg)):
        period: Schema = {}
        for pi, ld in enumerate(stage.period):
            period[f"p{pi}"] = _stack(layer_schema(cfg, ld), stage.repeat)
        schema["stages"][f"s{si}"] = period
    if not cfg.tie_embeddings:
        schema["lm_head"] = ParamSpec((d, v), ("embed", "vocab"),
                                      scale=0.02)
    if cfg.mtp_depth:
        # deepseek-v3 multi-token prediction: one extra block per depth,
        # sharing embedding and lm_head with the trunk.
        mtp_ld = LayerDef("mla" if cfg.use_mla else "attn", "dense")
        mcfg = cfg if cfg.d_ff else cfg.replace(d_ff=cfg.moe_d_ff * 4)
        schema["mtp"] = {
            "norm_h": _norm_schema(cfg, d),
            "norm_e": _norm_schema(cfg, d),
            "proj": ParamSpec((2 * d, d), (None, "embed")),
            "block": _stack(layer_schema(mcfg, mtp_ld), cfg.mtp_depth),
        }
    return schema
