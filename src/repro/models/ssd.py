"""Mamba-2 SSD (state-space duality) mixer — chunked scan + decode step.

The SSD recurrence  h_t = h_{t-1} * exp(dt_t A) + dt_t B_t x_t^T,
y_t = C_t h_t + D x_t  is evaluated chunk-by-chunk (`lax.scan` over chunks):
inside a chunk the quadratic "attention-like" dual form runs on the MXU;
across chunks the state is carried — O(L) memory, matmul-dominated compute,
which is exactly why SSD (vs Mamba-1's elementwise selective scan) is the
right TPU-native formulation (DESIGN.md §2).

Shapes: x (B, L, G, Hg, P) with H = G*Hg heads of dim P; B/C (B, L, G, N).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SSDState(NamedTuple):
    h: jax.Array          # (B, G, Hg, P, N) f32 SSM state
    conv: jax.Array       # (B, W-1, CH) conv tail


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, CH); w: (W, CH); b: (CH,)."""
    wlen = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(wlen):  # W is 4 — unrolled taps stay vectorized over L
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i]
    return (out + b).astype(x.dtype)


def conv1d_step(conv_state: jax.Array, x_new: jax.Array, w: jax.Array,
                b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. conv_state: (B, W-1, CH); x_new: (B, CH)."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)
    out = (window.astype(jnp.float32) * w[None]).sum(axis=1) + b
    return window[:, 1:, :], out.astype(x_new.dtype)


def ssd_chunked(
    x: jax.Array,        # (B, L, G, Hg, P)
    dt: jax.Array,       # (B, L, G, Hg)  — post-softplus
    a_log: jax.Array,    # (G, Hg)        — A = -exp(a_log)
    b_in: jax.Array,     # (B, L, G, N)
    c_in: jax.Array,     # (B, L, G, N)
    d_skip: jax.Array,   # (G, Hg)
    chunk: int,
    h0: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,G,Hg,P), final state (B,G,Hg,P,N))."""
    bsz, l, g, hg, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:  # tail-pad; dt=0 there, so padded steps are identity updates
        padfn = lambda t: jnp.pad(  # noqa: E731
            t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, b_in, c_in = map(padfn, (x, dt, b_in, c_in))
    l_pad = l + pad
    nc = l_pad // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))            # (G, Hg), negative

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))

    xc, dtc, bc, cc = map(to_chunks, (x, dt, b_in, c_in))
    # xc: (nc, B, Q, G, Hg, P); dtc: (nc, B, Q, G, Hg); bc/cc: (nc, B, Q, G, N)

    if h0 is None:
        h0 = jnp.zeros((bsz, g, hg, p, n), jnp.float32)

    def chunk_step(h, inp):
        xq, dtq, bq, cq = inp
        xq = xq.astype(jnp.float32)
        dtq = dtq.astype(jnp.float32)
        bq = bq.astype(jnp.float32)
        cq = cq.astype(jnp.float32)
        aq = dtq * A                                   # (B,Q,G,Hg) negative
        cs = jnp.cumsum(aq, axis=1)                    # decay from chunk start
        total = cs[:, -1]                              # (B,G,Hg)

        # intra-chunk dual (quadratic) form
        scores = jnp.einsum("bign,bjgn->bgij", cq, bq)  # (B,G,Q,Q)
        cs_t = cs.transpose(0, 2, 3, 1)                 # (B,G,Hg,Q)
        decay = jnp.exp(cs_t[..., :, None] - cs_t[..., None, :])
        iq = jnp.arange(chunk)
        causal = (iq[:, None] >= iq[None, :]).astype(jnp.float32)
        m = scores[:, :, None] * decay * causal
        m = m * dtq.transpose(0, 2, 3, 1)[..., None, :]  # fold dt_j
        y_intra = jnp.einsum("bghij,bjghp->bighp", m, xq)

        # contribution of carried state
        y_inter = jnp.einsum("bign,bghpn->bighp", cq, h)
        y_inter = y_inter * jnp.exp(cs)[..., None]

        # state update
        w_j = jnp.exp(total[:, None] - cs) * dtq        # (B,Q,G,Hg)
        s_new = jnp.einsum("bjgh,bjgn,bjghp->bghpn", w_j, bq, xq)
        h_new = h * jnp.exp(total)[..., None, None] + s_new

        y = y_intra + y_inter + xq * d_skip[None, None, :, :, None]
        return h_new, y.astype(x.dtype)

    h_final, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4, 5).reshape(bsz, l_pad, g, hg, p)
    return y[:, :l], h_final


def ssd_decode_step(
    h: jax.Array,        # (B, G, Hg, P, N) carried state
    x: jax.Array,        # (B, G, Hg, P) one token
    dt: jax.Array,       # (B, G, Hg)
    a_log: jax.Array,    # (G, Hg)
    b_in: jax.Array,     # (B, G, N)
    c_in: jax.Array,     # (B, G, N)
    d_skip: jax.Array,   # (G, Hg)
) -> Tuple[jax.Array, jax.Array]:
    """One-token SSM update. Returns (y (B,G,Hg,P), new state)."""
    A = -jnp.exp(a_log.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = jnp.exp(dtf * A)                               # (B,G,Hg)
    upd = jnp.einsum("bgh,bgn,bghp->bghpn", dtf, b_in.astype(jnp.float32), xf)
    h_new = h * da[..., None, None] + upd
    y = jnp.einsum("bgn,bghpn->bghp", c_in.astype(jnp.float32), h_new)
    y = y + xf * d_skip[None, :, :, None]
    return y.astype(x.dtype), h_new


def gated_rms_norm(y: jax.Array, z: jax.Array, gamma: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    """Mamba-2's norm(y * silu(z)) output gate."""
    dt = y.dtype
    yz = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yz * yz, axis=-1, keepdims=True)
    return ((yz * jax.lax.rsqrt(var + eps)) *
            (1.0 + gamma.astype(jnp.float32))).astype(dt)
