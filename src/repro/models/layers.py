"""Shared neural layers: norms, RoPE, blockwise attention, MLPs.

All attention is *blockwise* (FlashAttention-style tiling with running
max/denominator, pure ``lax.scan``): the 32k-prefill and 500k-decode shape
cells make materializing (S x S) score tensors impossible even at compile
time. Computation runs in f32 accumulators over bf16 operands.

Conventions: activations (B, S, D); attention internals (B, S, KVH, G, hd)
with G = n_heads // n_kv_heads (GQA groups); masks built from absolute
positions so the same code path serves causal, sliding-window, prefix-LM
and bidirectional (encoder) attention — and gemma3's scanned per-layer
local/global flag just widens the window dynamically.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))
            ).astype(dt)


def layer_norm(x, gamma, beta, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) [or (..., H, hd) with scalar positions]; rotates
    pairs (even, odd) across the last dim."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash) attention
# ---------------------------------------------------------------------------

class AttnSpec(NamedTuple):
    causal: bool = True
    window: int = 0          # 0 = unlimited; sliding window otherwise
    prefix_len: int = 0      # positions < prefix_len attend bidirectionally


def _mask(qi: jax.Array, kj: jax.Array, spec: AttnSpec,
          is_global: Optional[jax.Array]) -> jax.Array:
    """(bq, bkv) boolean allow-mask from absolute positions."""
    qi = qi[:, None]
    kj = kj[None, :]
    allow = jnp.ones(jnp.broadcast_shapes(qi.shape, kj.shape), bool)
    if spec.causal:
        causal_ok = kj <= qi
        if spec.prefix_len:
            causal_ok = causal_ok | (kj < spec.prefix_len)
        allow = allow & causal_ok
    if spec.window:
        in_window = (qi - kj) < spec.window
        if is_global is not None:
            in_window = in_window | is_global  # scanned per-layer flag
        allow = allow & in_window
    return allow


def flash_attention(
    q: jax.Array,          # (B, Sq, H, hd)
    k: jax.Array,          # (B, Skv, KVH, hd)
    v: jax.Array,          # (B, Skv, KVH, hd)
    spec: AttnSpec,
    *,
    q_offset: int | jax.Array = 0,
    is_global: Optional[jax.Array] = None,
    bq: int = 512,
    bkv: int = 1024,
) -> jax.Array:
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    hdv = v.shape[-1]                # may differ from hd (MLA)
    g = h // kvh
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    scale = hd ** -0.5

    qg = q.reshape(b, sq, kvh, g, hd)
    n_q, n_kv = sq // bq, skv // bkv

    # (n_q, B, bq, KVH, G, hd) / (n_kv, B, bkv, KVH, hd)
    q_blocks = qg.reshape(b, n_q, bq, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    k_blocks = k.reshape(b, n_kv, bkv, kvh, hd).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(b, n_kv, bkv, kvh, hdv).transpose(1, 0, 2, 3, 4)

    def q_step(_, qb_i):
        qb, iq = qb_i
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, kb_vb_j):
            m, l, acc = carry
            kb, vb, jk = kb_vb_j
            kpos = jk * bkv + jnp.arange(bkv)
            s = jnp.einsum("bihgd,bjhd->bhgij", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            allow = _mask(qpos, kpos, spec, is_global)
            s = jnp.where(allow[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgij,bjhd->bhgid", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, bq, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k_blocks, v_blocks, jnp.arange(n_kv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,KVH,G,bq,hdv)
        out = out.transpose(0, 3, 1, 2, 4)             # (B,bq,KVH,G,hdv)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (q_blocks, jnp.arange(n_q)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hdv)
    return out


def decode_attention(
    q: jax.Array,          # (B, H, hd) — one new token per sequence
    k_cache: jax.Array,    # (B, Smax, KVH, hd)
    v_cache: jax.Array,
    pos: jax.Array,        # (B,) current position (0-based index of new token)
    spec: AttnSpec,
    is_global: Optional[jax.Array] = None,
) -> jax.Array:
    b, h, hd = q.shape
    _, smax, kvh, _ = k_cache.shape
    hdv = v_cache.shape[-1]
    g = h // kvh
    scale = hd ** -0.5
    qg = q.reshape(b, kvh, g, hd)
    s = jnp.einsum("bhgd,bjhd->bhgj", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    j = jnp.arange(smax)[None, :]                       # (1, Smax)
    allow = j <= pos[:, None]
    if spec.window:
        in_w = (pos[:, None] - j) < spec.window
        if is_global is not None:
            in_w = in_w | is_global
        allow = allow & in_w
    s = jnp.where(allow[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgj,bjhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hdv).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ w_down


def gelu_mlp(x, w_fc, b_fc, w_proj, b_proj):
    h = jax.nn.gelu(x @ w_fc + b_fc)
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ w_proj + b_proj


# ---------------------------------------------------------------------------
# activation wire telemetry (SPARQLe serving path)
# ---------------------------------------------------------------------------

def act_wire_telemetry(x: jax.Array) -> dict:
    """Per-token wire accounting of a hidden-activation tensor (..., D).

    Int8-quantizes ``x`` per token and reports, per row:

      * ``sparsity``    — MSB4 sub-precision sparsity,
      * ``wire_bytes``  — MEASURED bytes in the packed wire format
        (``core/packing.py``: LSB4 pairs + PBM words + compacted MSB
        stream, including the padding/word-rounding slack),
      * ``dense_bytes`` — the dense int8 baseline (D bytes).

    The paged serving steps call this on the INTER-LAYER hidden (residual)
    stream — the tensor the paper's drain path writes back to SRAM in
    SPARQLe format between layers. It is a stream-level measurement, not
    the per-projection operand accounting: each projection additionally
    norms (and, with clipping enabled, §3.2-clips) its input before
    encoding, which shifts per-projection sparsity relative to the
    numbers reported here (bench_compression.py measures those per-site).
    """
    from repro.core.packing import (dense_bytes_rows,
                                    measured_wire_bytes_rows)
    from repro.core.quantize import quantize_activations
    from repro.core.sparqle import subprecision_sparsity

    q = quantize_activations(x, bits=8, per_token=True).q
    return {
        "sparsity": subprecision_sparsity(q, axis=-1),
        "wire_bytes": measured_wire_bytes_rows(q).astype(jnp.float32),
        "dense_bytes": jnp.full(q.shape[:-1], dense_bytes_rows(q),
                                jnp.float32),
    }


def stack_sublayer_telemetry(tels: list) -> dict:
    """Stack per-sub-layer telemetry dicts into per-key (period, ...) arrays.

    Shared by every paged step (decode / prefill-chunk / verify-window):
    inside the stage scan each sub-layer contributes one
    :func:`act_wire_telemetry` dict; the scan then stacks the period axis
    under the repeat axis and the caller flattens (repeat, period, ...)
    to per-layer rows.
    """
    return {k: jnp.stack([t[k] for t in tels], 0) for k in tels[0]}


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table_or_head: jax.Array, transpose: bool) -> jax.Array:
    w = table_or_head.T if transpose else table_or_head
    return x @ w.astype(x.dtype)
