"""Abstract SPARQLe-quantized parameter trees (dry-run substrate).

``build_quantized_schema`` mirrors :func:`repro.core.qlinear.
quantize_model_params` at the *schema* level: every quantizable projection
ParamSpec becomes a :class:`SparqleLinear` whose leaves are ParamSpecs for
the int8-container weight, per-output-channel scales, column-importance
mask and clipping constants. From that tree the dry-run derives
ShapeDtypeStructs and NamedShardings without allocating any memory — this
is how a 671B-param served model lowers on a laptop.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core.qlinear import SparqleLinear, is_quantizable
from repro.core.quantize import QuantizedTensor
from repro.distributed.sharding import spec_for
from repro.models.schema import ParamSpec


def _quantize_spec(spec: ParamSpec, path: str) -> SparqleLinear:
    shape, axes = spec.shape, spec.axes
    stacked = bool(axes) and axes[0] == "layers"
    is_expert = "experts" in axes          # routed-expert batched weight
    # identify (prefix dims, K, N): prefix = layer-stack and/or expert dims
    n_prefix = (1 if stacked else 0) + (1 if is_expert else 0)
    assert len(shape) == n_prefix + 2, (path, shape)
    pre_shape, (k, n) = shape[:n_prefix], shape[n_prefix:]
    pre_axes = axes[:n_prefix]
    k_ax, n_ax = axes[n_prefix], axes[n_prefix + 1]
    packed = k % 2 == 0                      # int4 nibbles two-per-byte
    q_shape = pre_shape + ((k // 2 if packed else k), n)
    q = ParamSpec(q_shape, axes, jnp.int8, init="zeros")
    scale = ParamSpec(pre_shape + (1, n), pre_axes + (None, n_ax),
                      jnp.float32, init="ones")
    zero = ParamSpec(pre_shape + (1, n), pre_axes + (None, n_ax),
                     jnp.float32, init="zeros")
    col_mask = ParamSpec(pre_shape + (k,), pre_axes + (k_ax,),
                         jnp.bool_, init="zeros")
    lh_shape = (shape[0],) if stacked else ()
    lh_axes = ("layers",) if stacked else ()
    l = ParamSpec(lh_shape, lh_axes, jnp.float32, init="zeros")
    h = ParamSpec(lh_shape, lh_axes, jnp.float32, init="zeros")
    return SparqleLinear(
        w=QuantizedTensor(q=q, scale=scale, zero=zero, bits=4),
        col_mask=col_mask, l=l, h=h, mode="sparqle", packed=packed)


def build_quantized_schema(schema: Dict[str, Any], w_bits: int = 4,
                           mode: str = "sparqle") -> Dict[str, Any]:
    """Schema tree with quantizable leaves replaced by SparqleLinear-of-spec."""

    def walk(tree, prefix=""):
        out = {}
        for key, v in tree.items():
            path = f"{prefix}/{key}" if prefix else key
            if isinstance(v, dict):
                out[key] = walk(v, path)
            elif isinstance(v, ParamSpec) and is_quantizable(path, _Probe(v)):
                sl = _quantize_spec(v, path)
                sl.w.bits = w_bits
                sl.mode = mode
                out[key] = sl
            else:
                out[key] = v
        return out

    return walk(schema)


class _Probe:
    """Adapter: is_quantizable checks .ndim on array leaves."""

    def __init__(self, spec: ParamSpec):
        self.ndim = len(spec.shape)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_abstract(tree) -> Any:
    """ParamSpec leaves -> ShapeDtypeStruct (works through SparqleLinear)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        tree, is_leaf=_is_spec)


def tree_shardings(tree, mesh: Mesh) -> Any:
    """ParamSpec leaves -> NamedSharding via the logical-axis rule table."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, spec_for(s.axes, s.shape, mesh)),
        tree, is_leaf=_is_spec)
