"""Checkpointing: npz shards + manifest, async writes, elastic restore.

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json      # step, tree structure, leaf shapes/dtypes, status
        shard_<i>.npz      # flattened leaves, chunked ~512MB per shard

A checkpoint is only valid once its manifest records ``"status": "complete"``
(written last — a process killed mid-write never yields a loadable but
corrupt state; ``latest_step`` skips incomplete ones). Writes go through a
background thread (``AsyncWriter``) so the train loop only blocks on the
previous write (one-deep pipeline, like Orbax async).

*Elastic restore*: leaves are stored as full (unsharded) logical arrays, so
a checkpoint written on one mesh restores onto any other mesh/topology —
``restore`` takes the target shardings and lays shards out accordingly.
Restoring a smaller/larger data-parallel world therefore "just works",
which is the checkpoint half of elastic scaling.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# pytree <-> flat leaves
# ---------------------------------------------------------------------------

def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


# npz can't represent ml_dtypes (bfloat16 etc.); store them as a same-width
# integer view and restore via the manifest's recorded dtype string.
_VIEW_FOR_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _encode_leaf(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(_VIEW_FOR_WIDTH[arr.dtype.itemsize])
    return arr


def _decode_leaf(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name == dtype_name:
        return arr
    try:
        target = np.dtype(dtype_name)
    except TypeError:
        import ml_dtypes
        target = np.dtype(getattr(ml_dtypes, dtype_name))
    if target.itemsize == arr.dtype.itemsize and arr.dtype.kind in "uiV":
        return arr.view(target)
    return arr.astype(target)


def save(path: str, tree: Any, step: int,
         shard_bytes: int = 512 * 2**20) -> str:
    """Synchronous checkpoint write. Returns the checkpoint directory."""
    ckdir = os.path.join(path, f"step_{step:09d}")
    tmp = ckdir + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    manifest: Dict[str, Any] = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                   for l in leaves],
        "shards": [],
        "status": "writing",
    }
    shard, size, shard_idx = {}, 0, 0

    def flush():
        nonlocal shard, size, shard_idx
        if not shard:
            return
        np.savez(os.path.join(tmp, f"shard_{shard_idx}.npz"), **shard)
        manifest["shards"].append(
            {"file": f"shard_{shard_idx}.npz", "keys": sorted(shard)})
        shard, size, shard_idx = {}, 0, shard_idx + 1

    for i, leaf in enumerate(leaves):
        shard[f"leaf_{i}"] = _encode_leaf(leaf)
        size += leaf.nbytes
        if size >= shard_bytes:
            flush()
    flush()

    manifest["status"] = "complete"
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckdir):
        shutil.rmtree(ckdir)
    os.rename(tmp, ckdir)          # atomic publish
    return ckdir


def latest_step(path: str) -> Optional[int]:
    """Largest step with a complete manifest, or None."""
    if not os.path.isdir(path):
        return None
    best = None
    for name in os.listdir(path):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        man = os.path.join(path, name, "manifest.json")
        try:
            with open(man) as f:
                if json.load(f).get("status") != "complete":
                    continue
        except (OSError, json.JSONDecodeError):
            continue
        step = int(m.group(1))
        best = step if best is None else max(best, step)
    return best


def restore(path: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings (same structure) —
    leaves are device_put with them, which is what makes restore *elastic*:
    the stored arrays are logical/unsharded, the target mesh is free.
    """
    ckdir = os.path.join(path, f"step_{step:09d}")
    with open(os.path.join(ckdir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["status"] == "complete", ckdir
    flat: Dict[str, np.ndarray] = {}
    for sh in manifest["shards"]:
        with np.load(os.path.join(ckdir, sh["file"])) as z:
            for k in sh["keys"]:
                flat[k] = z[k]
    leaves_ref, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves_ref) == manifest["n_leaves"], (
        len(leaves_ref), manifest["n_leaves"])
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_ref))
    for i, ref in enumerate(leaves_ref):
        arr = _decode_leaf(flat[f"leaf_{i}"],
                           manifest["leaves"][i]["dtype"])
        assert tuple(arr.shape) == tuple(ref.shape), (
            i, arr.shape, ref.shape)
        a = jnp.asarray(arr, dtype=ref.dtype)
        if shard_leaves[i] is not None:
            a = jax.device_put(a, shard_leaves[i])
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


def prune(path: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(path):
        return
    steps = sorted(
        int(m.group(1)) for m in
        (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(path)) if m)
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(path, f"step_{s:09d}"), ignore_errors=True)


# ---------------------------------------------------------------------------
# async writer (one-deep pipeline)
# ---------------------------------------------------------------------------

class AsyncWriter:
    """Background checkpoint writer; the step loop never blocks on I/O
    (except to bound the pipeline at one in-flight write)."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step = item
            try:
                save(self.path, tree, step)
                prune(self.path, self.keep)
            except BaseException as e:   # surfaced on next submit/close
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, tree: Any, step: int) -> None:
        if self._err:
            raise RuntimeError("async checkpoint write failed") from self._err
        # materialize on host *now* so the step loop can donate buffers
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._q.put((host_tree, step))

    def close(self) -> None:
        self._q.join()
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise RuntimeError("async checkpoint write failed") from self._err
