"""Continuous-batching serving engine over the paged packed-KV4 pool.

Ties together the scheduler (admission / chunked prefill / decode batch
formation), the page pool (wire-format KV storage), and two jitted step
functions (launch/steps.py):

  * ``prefill_chunk`` — one (1, prefill_chunk) slice of one prompt;
  * ``decode``        — one token for every decode slot at once, through
    the paged decode-attention Pallas kernel.

Both are shape-static (chunk width, decode batch width, block-table
width), so the whole serving loop compiles exactly twice. Inactive
decode slots ride along pointing at the pool's null page.

    eng = Engine(cfg, qparams)
    h = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=8))
    for tok in eng.stream(h):
        ...
    print(h.stats())
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import check_paged_support
from repro.obs import Observability
from repro.serving.kv_pool import PagedKVPool, PoolConfig
from repro.serving.scheduler import (Request, SamplingParams, Scheduler,
                                     SchedulerConfig)


class Engine:
    def __init__(self, cfg: ModelConfig, params,
                 pool_config: Optional[PoolConfig] = None,
                 sched_config: Optional[SchedulerConfig] = None,
                 clock=time.monotonic, mesh=None,
                 obs: Optional[Observability] = None,
                 slos=None):
        """``mesh`` (a ("data", "model") Mesh, e.g. ``make_smoke_mesh``)
        makes the engine mesh-native: the jitted steps run inside
        shard_map with weights tensor-parallel on "model", the paged pool
        sharded on kv_heads over "model" and pages over "data", and
        decode slots partitioned over "data". The public API and the
        greedy token streams are unchanged — sharded steps are bit-exact
        vs the single-device ones (docs/sharding.md). A 1-device mesh
        (or None) keeps the original single-device path.

        ``obs`` (``repro.obs.Observability``) is the engine's metrics
        registry + span tracer; by default the engine creates its own
        around ``clock``. Every layer of the stack reports into it
        (docs/observability.md) and it backs ``aggregate_stats()``,
        ``metrics_snapshot()`` and the ``--metrics-out``/``--trace-out``
        artifacts. Instrumentation is host-side only — the traced/jitted
        step programs are unchanged.

        ``slos`` (iterable of ``repro.obs.slo.SLO``) arms the SLO
        watchdog: the engine feeds ``ttft``/``tpot`` at emit time and
        ``queue_depth`` once per scheduler iteration, and violations
        show up as counters + trace instants (docs/observability.md
        §SLOs).
        """
        from repro.launch import steps as S
        from repro.obs.slo import attach_engine_slos
        check_paged_support(cfg)
        self.cfg = cfg
        self._clock = clock
        self.obs = obs if obs is not None else Observability(clock=clock)
        self._init_metrics()
        self.slo = attach_engine_slos(self, slos)
        self._attr = None  # StepAttribution, built by attribute_steps()
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        pool_config = pool_config or PoolConfig()
        sched_config = sched_config or SchedulerConfig()
        if self.mesh is not None:
            from repro.distributed import tp
            mways = tp.mesh_axis_size(self.mesh, "model")
            dways = tp.mesh_axis_size(self.mesh, "data")
            tp.validate_tp_config(cfg, mways)
            if sched_config.max_decode_batch % dways:
                raise ValueError(
                    f"max_decode_batch={sched_config.max_decode_batch} "
                    f"must divide over the data axis ({dways}): each data "
                    f"shard owns a contiguous slice of decode slots")
            self._data_ways = dways
            self._param_specs = tp.param_pspecs(params, axis="model")
            self._pool_specs = tp.pool_pspecs(cfg, pool_config, self.mesh)
            params = tp.device_put_tree(params, self._param_specs,
                                        self.mesh)
        else:
            self._data_ways = 1
            self._param_specs = self._pool_specs = None
        self.params = params
        self.pool = PagedKVPool(cfg, pool_config,
                                n_shards=self._data_ways, obs=self.obs)
        if self.mesh is not None:
            from repro.distributed import tp
            self.pool.state = tp.device_put_tree(
                self.pool.state, self._pool_specs, self.mesh)
        # KV2 precision ladder: armed by PoolConfig.kv2_pages > 0. The
        # decode step gains a tier-table argument and routes each page
        # through the slab its tier id names; demotion/promotion policy
        # runs host-side around the step (docs/serving.md §ladder).
        self._kv2 = self.pool.kv2_armed
        if self._kv2 and self.mesh is not None:
            raise NotImplementedError(
                "the KV2 precision ladder is unsharded-only "
                "(kv2_pages > 0 with a mesh is not wired up)")
        self.sched = Scheduler(self.pool, sched_config, obs=self.obs)
        scfg = self.sched.cfg
        self._chunk = scfg.prefill_chunk
        self._n_slots = scfg.max_decode_batch
        self._n_page_steps = scfg.max_pages_per_seq
        # donate the pool state: the old pages buffer is dead the moment a
        # step returns, and without aliasing every token would copy the
        # whole pool (exactly the HBM traffic the paged design removes)
        self._prefill_fn = jax.jit(
            S.make_engine_prefill_chunk(cfg, mesh=self.mesh,
                                        param_specs=self._param_specs,
                                        pool_specs=self._pool_specs),
            donate_argnums=(1,))
        self._decode_fn = jax.jit(
            S.make_engine_decode(cfg, kv2=self._kv2, mesh=self.mesh,
                                 param_specs=self._param_specs,
                                 pool_specs=self._pool_specs),
            donate_argnums=(1,))
        self._rngs: Dict[int, np.random.Generator] = {}
        self.steps = 0
        # per-layer measured wire-format telemetry (lazily sized (L,) on
        # the first step's telemetry): MEASURED packed activation bytes vs
        # the dense int8 baseline, plus token-weighted MSB4 sparsity,
        # summed over every telemetered token
        self.layer_wire_bytes: Optional[np.ndarray] = None
        self.layer_dense_bytes: Optional[np.ndarray] = None
        self.layer_sparsity_sum: Optional[np.ndarray] = None
        self.wire_tokens = 0

    def _init_metrics(self) -> None:
        """Register the engine's metrics (idempotent via the registry's
        create-or-get). Scheduler/pool metrics register in their own
        constructors against the same registry."""
        r = self.obs.registry
        self._m_steps = r.counter(
            "serving_engine_steps_total", "scheduler iterations run",
            unit="steps")
        self._m_tokens = r.counter(
            "serving_tokens_processed_total", "compute tokens through the "
            "jitted steps, by phase", unit="tokens", labelnames=("phase",))
        self._m_emitted = r.counter(
            "serving_tokens_emitted_total", "sampled tokens handed to "
            "requests", unit="tokens")
        self._m_ttft = r.histogram(
            "serving_ttft_seconds", "request arrival to first emitted "
            "token", unit="seconds")
        self._m_tpot = r.histogram(
            "serving_tpot_seconds", "gap between consecutive emitted "
            "tokens of one request", unit="seconds")
        self._m_step_lat = r.histogram(
            "serving_step_seconds", "host-side latency of one engine-step "
            "phase (includes device sync)", unit="seconds",
            labelnames=("phase",))
        self._m_wire = r.counter(
            "serving_wire_bytes_total", "measured packed-wire activation "
            "bytes (inter-layer hidden stream)", unit="bytes")
        self._m_dense = r.counter(
            "serving_dense_bytes_total", "dense int8 baseline bytes for "
            "the same activations", unit="bytes")
        self._g_pool_free = r.gauge(
            "serving_pool_pages_free", "free pages across all shards",
            unit="pages")
        self._g_pool_util = r.gauge(
            "serving_pool_utilization_ratio", "fraction of usable pages "
            "allocated", unit="ratio")
        self._g_layer_wire = r.gauge(
            "serving_layer_wire_bytes_per_token", "measured wire bytes "
            "per telemetered token entering each layer", unit="bytes",
            labelnames=("layer",))
        self._g_layer_sparsity = r.gauge(
            "serving_layer_msb_sparsity_ratio", "token-weighted MSB4 "
            "sub-precision sparsity of the hidden stream entering each "
            "layer", unit="ratio", labelnames=("layer",))
        self._g_kv2_used = r.gauge(
            "serving_pool_kv2_pages_used", "pages currently held at the "
            "KV2 tier (0 when the ladder is disarmed)", unit="pages")
        self._g_kv_saved = r.gauge(
            "serving_pool_kv_bytes_saved", "KV HBM bytes currently freed "
            "by demoted pages (KV4 cost minus KV2 cost of held KV2 "
            "pages)", unit="bytes")

    # -- public API --------------------------------------------------------

    def submit(self, prompt: List[int],
               sampling: SamplingParams = SamplingParams()) -> Request:
        """Enqueue a request; returns its handle (tokens land on
        ``handle.out_tokens`` as the engine steps)."""
        return self.sched.submit([int(t) for t in prompt], sampling,
                                 self._clock())

    def stream(self, req: Request) -> Iterator[int]:
        """Drive the engine until ``req`` finishes, yielding its tokens
        as they are produced (other in-flight requests progress too)."""
        seen = 0
        while True:
            while seen < len(req.out_tokens):
                yield req.out_tokens[seen]
                seen += 1
            if req.done:
                return
            self.step()

    def run(self, max_steps: int = 100_000) -> None:
        """Step until every submitted request has finished."""
        for _ in range(max_steps):
            if not self.sched.has_work():
                return
            self.step()
        raise RuntimeError(f"engine did not drain in {max_steps} steps")

    def step(self) -> List[Tuple[int, int]]:
        """One scheduler iteration. Returns [(rid, token), ...] emitted.

        Each phase (schedule / per-chunk prefill / decode batch) is timed
        into ``serving_step_seconds{phase=}`` and spanned on the tracer's
        engine track — all host-side, around (never inside) the jitted
        calls.
        """
        tr = self.obs.tracer
        events: List[Tuple[int, int]] = []
        with tr.span("engine_step", step=self.steps):
            if self._kv2:
                self.pool.tick()
            with self._m_step_lat.time(phase="schedule"):
                plan = self.sched.schedule()
            if self.slo is not None:
                self.slo.observe("queue_depth", float(len(self.sched.waiting)))
            for req, start, n in plan.prefill:
                with tr.span("prefill_chunk", rid=req.rid, start=start,
                             n=n):
                    with self._m_step_lat.time(phase="prefill"):
                        events.extend(
                            self._run_prefill_chunk(req, start, n))
                self._m_tokens.inc(n, phase="prefill")
            if plan.decode:
                with tr.span("decode_batch", slots=len(plan.decode)):
                    with self._m_step_lat.time(phase="decode"):
                        events.extend(self._run_decode(plan.decode))
            if self._kv2:
                # background cold sweep AFTER the decode writes landed:
                # a page demoted here is first read (tier-routed) next
                # step, so the step that demotes never races its reader
                with self._m_step_lat.time(phase="demote"):
                    self.pool.demote_cold()
        self._m_steps.inc()
        self.steps += 1
        return events

    # -- performance attribution ------------------------------------------

    def attribute_steps(self, hw=None):
        """Attribute the engine's jitted steps against their compiled HLO.

        Lowers + compiles each serving step (prefill_chunk / decode; the
        speculative engine extends this with draft / verify) against
        abstract avals of its real runtime arguments — same shapes,
        dtypes and shardings, so the analyzed program is the SPMD
        program the engine executes — and registers per-step FLOPs, HBM
        bytes and collective bytes (``serving_step_attr_*``). Explicit
        and idempotent: call once after construction (the bench and
        ``serve.py --attribute`` do); re-attribution is a no-op.

        ``hw`` (``costmodel.HardwareConfig``) sets the roofline peaks
        and the cost-model latency predictor's substrate; defaults to
        the paper's reference config. Returns the ``StepAttribution``.
        """
        from repro.obs.attribution import StepAttribution
        if self._attr is None:
            self._attr = StepAttribution(self.obs, hw=hw)
        sds = jax.ShapeDtypeStruct
        params_a, pool_a = self._attr_abstract_args()
        if "prefill" not in self._attr.phases():
            self._attr.attribute(
                "prefill", self._prefill_fn,
                (params_a, pool_a, sds((1, self._chunk), jnp.int32),
                 sds((), jnp.int32), sds((), jnp.int32),
                 sds((self._data_ways, self._n_page_steps), jnp.int32)),
                tokens_per_step=self._chunk,
                predict_seconds=self._phase_predictor("prefill"))
        if "decode" not in self._attr.phases():
            decode_avals = (params_a, pool_a,
                            sds((self._n_slots,), jnp.int32),
                            sds((self._n_slots,), jnp.int32),
                            sds((self._n_slots, self._n_page_steps),
                                jnp.int32))
            if self._kv2:  # tier tables ride after the block tables
                decode_avals += (
                    sds((self._n_slots, self._n_page_steps), jnp.int32),)
            self._attr.attribute(
                "decode", self._decode_fn, decode_avals,
                tokens_per_step=self._n_slots,
                predict_seconds=self._phase_predictor("decode"))
        return self._attr

    def _attr_abstract_args(self):
        from repro.launch import steps as S
        return S.abstract_like(self.params), S.abstract_like(self.pool.state)

    def _costmodel_shape(self):
        """The engine config as a ``costmodel.LMShape`` (same mapping
        ``launch/serve.py`` uses for its analytic report)."""
        from repro.core import costmodel as CM
        cfg = self.cfg
        return CM.LMShape(cfg.name, cfg.n_layers, cfg.d_model,
                          max(1, cfg.n_heads), max(1, cfg.n_kv_heads),
                          max(1, cfg.d_ff or cfg.moe_d_ff), cfg.vocab,
                          w_bits=cfg.w_bits)

    def _phase_predictor(self, phase: str):
        """sparsity -> predicted seconds/step closure over
        ``costmodel.phase_cost`` (paper §4, Table 1 substrate)."""
        from repro.core import costmodel as CM
        shape = self._costmodel_shape()
        hw = self._attr.hw
        decode = phase != "prefill"
        m_tokens = self._chunk if phase == "prefill" else self._n_slots
        seq_for_attn = self._n_page_steps * self.pool.page_size

        def predict(sparsity: float) -> float:
            layers = CM.lm_linear_layers(
                shape, m_tokens, sparsity, seq_for_attn=seq_for_attn,
                decode=decode)
            cost = CM.phase_cost(layers, hw, sparqle=True)
            return cost.cycles / (hw.freq_ghz * 1e9)
        return predict

    def aggregate_stats(self) -> Dict[str, float]:
        """Pool-level counters to pair with per-request ``req.stats()``.

        ``wire_*`` keys report the MEASURED packed-wire-format accounting
        of the inter-layer hidden activation stream (core/packing.py
        layout; ``models.layers.act_wire_telemetry``), per layer and in
        aggregate — the engine's view of what Eq. 1 predicts
        analytically. Stream-level, not per-projection: norm/clipping
        inside each layer shifts per-projection operand sparsity
        (bench_compression.py measures those sites).

        Integer counters read back from the metrics registry (they are
        incremented at the same sites that used to maintain ad-hoc
        attributes, so the values are identical); the ``wire_*`` floats
        stay sourced from the engine's float64 accumulation arrays so
        summation order — and therefore every historical digit — is
        unchanged.
        """
        self._refresh_gauges()
        r = self.obs.registry
        out = {
            "steps": int(r.value("serving_engine_steps_total")),
            "pool_pages_free": int(r.value("serving_pool_pages_free")),
            "pool_utilization": float(
                r.value("serving_pool_utilization_ratio")),
            "pool_evictions": int(r.value("serving_pool_evictions_total")),
        }
        if self._kv2:
            out["pool_demotions"] = int(
                r.value("serving_pool_demotions_total"))
            out["pool_promotions"] = int(
                r.value("serving_pool_promotions_total"))
            out["kv_bytes_reclaimed"] = int(
                r.value("serving_pool_kv_bytes_reclaimed_total"))
            out["kv2_pages_used"] = int(self.pool.kv2_used)
            out["kv_bytes_saved"] = int(self.pool.kv_bytes_saved())
        if self.layer_wire_bytes is not None and self.wire_tokens:
            wire = float(self.layer_wire_bytes.sum())
            dense = float(self.layer_dense_bytes.sum())
            out["wire_bytes_total"] = wire
            out["wire_compression_pct"] = (1.0 - wire / dense) * 100.0
            out["layer_wire_bytes_per_token"] = (
                self.layer_wire_bytes / self.wire_tokens).tolist()
            out["layer_dense_bytes_per_token"] = (
                self.layer_dense_bytes / self.wire_tokens).tolist()
        return out

    def _refresh_gauges(self) -> None:
        """Push point-in-time state into the registry gauges. Called on
        read (``aggregate_stats``/``metrics_snapshot``), not per step, so
        the hot path never pays for them."""
        self._g_pool_free.set(self.pool.num_free)
        self._g_pool_util.set(self.pool.utilization())
        self._g_kv2_used.set(self.pool.kv2_used)
        self._g_kv_saved.set(self.pool.kv_bytes_saved())
        if self.layer_wire_bytes is not None and self.wire_tokens:
            per_tok = self.layer_wire_bytes / self.wire_tokens
            spars = self.layer_sparsity_sum / self.wire_tokens
            for i in range(per_tok.shape[0]):
                self._g_layer_wire.set(float(per_tok[i]), layer=str(i))
                self._g_layer_sparsity.set(float(spars[i]), layer=str(i))
        self._join_attribution()

    def _join_attribution(self) -> None:
        """Join attributed step costs with measured step wall-times into
        the roofline/drift gauges (read-time, like the other gauges)."""
        if self._attr is None:
            return
        mean_sparsity = 0.0
        if self.layer_sparsity_sum is not None and self.wire_tokens:
            mean_sparsity = float(
                self.layer_sparsity_sum.mean() / self.wire_tokens)
        for phase in self._attr.phases():
            n = self._m_step_lat.count(phase=phase)
            if n:
                self._attr.observe_runtime(
                    phase, self._m_step_lat.mean(phase=phase),
                    sparsity=mean_sparsity)
        if self.layer_wire_bytes is not None and self.wire_tokens:
            from repro.core.packing import PBM_WORD_BITS, pad_k
            kp = pad_k(self.cfg.d_model)
            fixed = kp / 2.0 + (kp // PBM_WORD_BITS) * 4.0  # LSB4 + PBM
            spars = self.layer_sparsity_sum / self.wire_tokens
            predicted = float(sum(fixed + (1.0 - s) * kp / 2.0
                                  for s in spars))  # Eq. 1 per layer
            measured = float(self.layer_wire_bytes.sum() / self.wire_tokens)
            self._attr.observe_wire(measured, predicted)

    def metrics_snapshot(self) -> Dict[str, object]:
        """Refresh gauges and return the full registry snapshot
        (``repro.obs.MetricsRegistry.snapshot`` schema)."""
        self._refresh_gauges()
        return self.obs.registry.snapshot()

    def _account_wire(self, req: Request, wire: float, dense: float,
                      layer_wire: np.ndarray, layer_dense: np.ndarray,
                      layer_spars_weighted: np.ndarray,
                      n_tokens: int) -> None:
        """Fold one telemetered slab of tokens into the byte accounting.

        ``layer_spars_weighted`` is per-layer MSB sparsity already scaled
        by ``n_tokens`` so the engine-level accumulator stays a plain
        token-weighted sum. Draft (LSB4-only) tokens never reach here —
        they carry no telemetry — so ``wire_tokens`` is exactly the
        denominator the byte totals were measured over.
        """
        req.wire_bytes_sum += wire
        req.dense_bytes_sum += dense
        req.wire_tokens += n_tokens
        if self.layer_wire_bytes is None:
            self.layer_wire_bytes = np.zeros(layer_wire.shape[0], np.float64)
            self.layer_dense_bytes = np.zeros(layer_wire.shape[0], np.float64)
            self.layer_sparsity_sum = np.zeros(
                layer_wire.shape[0], np.float64)
        self.layer_wire_bytes += layer_wire
        self.layer_dense_bytes += layer_dense
        self.layer_sparsity_sum += layer_spars_weighted
        self.wire_tokens += n_tokens
        self._m_wire.inc(wire)
        self._m_dense.inc(dense)

    # -- internals ---------------------------------------------------------

    def _block_table_row(self, req: Request) -> np.ndarray:
        row = np.zeros((self._n_page_steps,), np.int32)
        pages = self.pool.pages_of(req.rid)
        row[:len(pages)] = pages
        return row

    def _tier_table_row(self, req: Request) -> np.ndarray:
        """Per-page tier ids parallel to :meth:`_block_table_row` (the
        padded tail is tier 0, matching the KV4 null page it points at)."""
        row = np.zeros((self._n_page_steps,), np.int32)
        tiers = self.pool.tiers_of(req.rid)
        row[:len(tiers)] = tiers
        return row

    def _prefill_tables(self, req: Request) -> np.ndarray:
        """(D, Pmax) block table for the prefill step: one row per data
        shard, the owning shard's row holding the request's (shard-local)
        pages, every other row all-null (D = 1 without a mesh)."""
        tables = np.zeros((self._data_ways, self._n_page_steps), np.int32)
        tables[self.pool.shard_of(req.rid)] = self._block_table_row(req)
        return tables

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        t = req.sampling.temperature
        if t <= 0.0:
            return int(np.argmax(logits))
        rng = self._rngs.setdefault(
            req.rid, np.random.default_rng(req.sampling.seed + req.rid))
        z = (logits.astype(np.float64) - logits.max()) / t
        p = np.exp(z)
        p /= p.sum()
        return int(rng.choice(len(p), p=p))

    def _emit(self, req: Request, token: int) -> Optional[Tuple[int, int]]:
        now = self._clock()
        if req.t_first is None:
            req.t_first = now
            ttft = now - req.arrival
            self._m_ttft.observe(ttft)
            if self.slo is not None:
                self.slo.observe("ttft", ttft)
        elif req.t_last is not None:
            tpot = now - req.t_last
            self._m_tpot.observe(tpot)
            if self.slo is not None:
                self.slo.observe("tpot", tpot)
        req.t_last = now
        self._m_emitted.inc()
        req.context.append(token)
        req.out_tokens.append(token)
        s = req.sampling
        if (req.n_generated >= s.max_new_tokens or
                (s.stop_token is not None and token == s.stop_token)):
            self.sched.finish(req)
            self._rngs.pop(req.rid, None)
        return (req.rid, token)

    def _run_prefill_chunk(self, req: Request, start: int,
                           n: int) -> List[Tuple[int, int]]:
        toks = np.zeros((1, self._chunk), np.int32)
        toks[0, :n] = req.context[start:start + n]
        logits, self.pool.state, tel = self._prefill_fn(
            self.params, self.pool.state, jnp.asarray(toks),
            jnp.asarray(start, jnp.int32), jnp.asarray(n, jnp.int32),
            jnp.asarray(self._prefill_tables(req)))
        req.sparsity_sum += float(tel["sparsity"]) * n
        req.sparsity_n += n
        layer_wire = np.asarray(tel["layer_wire_bytes"], np.float64)
        layer_dense = np.asarray(tel["layer_dense_bytes"], np.float64)
        layer_spars = np.asarray(tel["layer_sparsity"], np.float64)
        self._account_wire(req, float(layer_wire.sum()),
                           float(layer_dense.sum()), layer_wire,
                           layer_dense, layer_spars * n, n)
        if not self.sched.prefill_advanced(req, n):
            return []
        self.sched.to_running(req)
        ev = self._emit(req, self._sample(req, np.asarray(logits[0])))
        return [ev] if ev else []

    def _run_decode(self, decode: List[Request]) -> List[Tuple[int, int]]:
        B = self._n_slots
        token = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        tables = np.zeros((B, self._n_page_steps), np.int32)
        if self._kv2:
            # touch BEFORE snapshotting tables: this step writes K/V at
            # pos, so the page covering it must be KV4 (promote-on-touch)
            # and its coldness stamp refreshed. Touching may swap page
            # ids, hence the ordering.
            ps = self.pool.page_size
            for req in decode:
                fp = (len(req.context) - 1) // ps
                self.pool.touch(req.rid, fp, fp)
            tiers = np.zeros((B, self._n_page_steps), np.int32)
            for req in decode:
                tiers[req.slot] = self._tier_table_row(req)
        for req in decode:
            token[req.slot] = req.context[-1]
            pos[req.slot] = len(req.context) - 1
            tables[req.slot] = self._block_table_row(req)
        if self._kv2:
            logits, self.pool.state, tel = self._decode_fn(
                self.params, self.pool.state, jnp.asarray(token),
                jnp.asarray(pos), jnp.asarray(tables), jnp.asarray(tiers))
        else:
            logits, self.pool.state, tel = self._decode_fn(
                self.params, self.pool.state, jnp.asarray(token),
                jnp.asarray(pos), jnp.asarray(tables))
        logits = np.asarray(logits)
        sparsity = np.asarray(tel["sparsity"])
        layer_wire = np.asarray(tel["layer_wire_bytes"], np.float64)
        layer_dense = np.asarray(tel["layer_dense_bytes"], np.float64)
        layer_spars = np.asarray(tel["layer_sparsity"], np.float64)
        events = []
        for req in decode:
            req.sparsity_sum += float(sparsity[req.slot])
            req.sparsity_n += 1
            self._account_wire(
                req, float(layer_wire[:, req.slot].sum()),
                float(layer_dense[:, req.slot].sum()),
                layer_wire[:, req.slot], layer_dense[:, req.slot],
                layer_spars[:, req.slot], 1)
            ev = self._emit(req, self._sample(req, logits[req.slot]))
            if ev:
                events.append(ev)
        self._m_tokens.inc(len(decode), phase="decode")
        return events
