"""Continuous-batching serving engine over the paged packed-KV4 pool.

Ties together the scheduler (admission / chunked prefill / decode batch
formation), the page pool (wire-format KV storage), and two jitted step
functions (launch/steps.py):

  * ``prefill_chunk`` — one (1, prefill_chunk) slice of one prompt;
  * ``decode``        — one token for every decode slot at once, through
    the paged decode-attention Pallas kernel.

Both are shape-static (chunk width, decode batch width, block-table
width), so the whole serving loop compiles exactly twice. Inactive
decode slots ride along pointing at the pool's null page.

    eng = Engine(cfg, qparams)
    h = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=8))
    for tok in eng.stream(h):
        ...
    print(h.stats())
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import check_paged_support
from repro.serving.kv_pool import PagedKVPool, PoolConfig
from repro.serving.scheduler import (FINISHED, Request, SamplingParams,
                                     Scheduler, SchedulerConfig)


class Engine:
    def __init__(self, cfg: ModelConfig, params,
                 pool_config: Optional[PoolConfig] = None,
                 sched_config: Optional[SchedulerConfig] = None,
                 clock=time.monotonic, mesh=None):
        """``mesh`` (a ("data", "model") Mesh, e.g. ``make_smoke_mesh``)
        makes the engine mesh-native: the jitted steps run inside
        shard_map with weights tensor-parallel on "model", the paged pool
        sharded on kv_heads over "model" and pages over "data", and
        decode slots partitioned over "data". The public API and the
        greedy token streams are unchanged — sharded steps are bit-exact
        vs the single-device ones (docs/sharding.md). A 1-device mesh
        (or None) keeps the original single-device path.
        """
        from repro.launch import steps as S
        check_paged_support(cfg)
        self.cfg = cfg
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        pool_config = pool_config or PoolConfig()
        sched_config = sched_config or SchedulerConfig()
        if self.mesh is not None:
            from repro.distributed import tp
            mways = tp.mesh_axis_size(self.mesh, "model")
            dways = tp.mesh_axis_size(self.mesh, "data")
            tp.validate_tp_config(cfg, mways)
            if sched_config.max_decode_batch % dways:
                raise ValueError(
                    f"max_decode_batch={sched_config.max_decode_batch} "
                    f"must divide over the data axis ({dways}): each data "
                    f"shard owns a contiguous slice of decode slots")
            self._data_ways = dways
            self._param_specs = tp.param_pspecs(params, axis="model")
            self._pool_specs = tp.pool_pspecs(cfg, pool_config, self.mesh)
            params = tp.device_put_tree(params, self._param_specs,
                                        self.mesh)
        else:
            self._data_ways = 1
            self._param_specs = self._pool_specs = None
        self.params = params
        self.pool = PagedKVPool(cfg, pool_config,
                                n_shards=self._data_ways)
        if self.mesh is not None:
            from repro.distributed import tp
            self.pool.state = tp.device_put_tree(
                self.pool.state, self._pool_specs, self.mesh)
        self.sched = Scheduler(self.pool, sched_config)
        self._clock = clock
        scfg = self.sched.cfg
        self._chunk = scfg.prefill_chunk
        self._n_slots = scfg.max_decode_batch
        self._n_page_steps = scfg.max_pages_per_seq
        # donate the pool state: the old pages buffer is dead the moment a
        # step returns, and without aliasing every token would copy the
        # whole pool (exactly the HBM traffic the paged design removes)
        self._prefill_fn = jax.jit(
            S.make_engine_prefill_chunk(cfg, mesh=self.mesh,
                                        param_specs=self._param_specs,
                                        pool_specs=self._pool_specs),
            donate_argnums=(1,))
        self._decode_fn = jax.jit(
            S.make_engine_decode(cfg, mesh=self.mesh,
                                 param_specs=self._param_specs,
                                 pool_specs=self._pool_specs),
            donate_argnums=(1,))
        self._rngs: Dict[int, np.random.Generator] = {}
        self.steps = 0
        # per-layer measured wire-format telemetry (lazily sized (L,) on
        # the first step's telemetry): MEASURED packed activation bytes vs
        # the dense int8 baseline, summed over every processed token
        self.layer_wire_bytes: Optional[np.ndarray] = None
        self.layer_dense_bytes: Optional[np.ndarray] = None
        self.wire_tokens = 0

    # -- public API --------------------------------------------------------

    def submit(self, prompt: List[int],
               sampling: SamplingParams = SamplingParams()) -> Request:
        """Enqueue a request; returns its handle (tokens land on
        ``handle.out_tokens`` as the engine steps)."""
        return self.sched.submit([int(t) for t in prompt], sampling,
                                 self._clock())

    def stream(self, req: Request) -> Iterator[int]:
        """Drive the engine until ``req`` finishes, yielding its tokens
        as they are produced (other in-flight requests progress too)."""
        seen = 0
        while True:
            while seen < len(req.out_tokens):
                yield req.out_tokens[seen]
                seen += 1
            if req.done:
                return
            self.step()

    def run(self, max_steps: int = 100_000) -> None:
        """Step until every submitted request has finished."""
        for _ in range(max_steps):
            if not self.sched.has_work():
                return
            self.step()
        raise RuntimeError(f"engine did not drain in {max_steps} steps")

    def step(self) -> List[Tuple[int, int]]:
        """One scheduler iteration. Returns [(rid, token), ...] emitted."""
        plan = self.sched.schedule()
        events: List[Tuple[int, int]] = []
        for req, start, n in plan.prefill:
            events.extend(self._run_prefill_chunk(req, start, n))
        if plan.decode:
            events.extend(self._run_decode(plan.decode))
        self.steps += 1
        return events

    def aggregate_stats(self) -> Dict[str, float]:
        """Pool-level counters to pair with per-request ``req.stats()``.

        ``wire_*`` keys report the MEASURED packed-wire-format accounting
        of the inter-layer hidden activation stream (core/packing.py
        layout; ``models.layers.act_wire_telemetry``), per layer and in
        aggregate — the engine's view of what Eq. 1 predicts
        analytically. Stream-level, not per-projection: norm/clipping
        inside each layer shifts per-projection operand sparsity
        (bench_compression.py measures those sites).
        """
        out = {
            "steps": self.steps,
            "pool_pages_free": self.pool.num_free,
            "pool_utilization": self.pool.utilization(),
            "pool_evictions": self.pool.evictions,
        }
        if self.layer_wire_bytes is not None and self.wire_tokens:
            wire = float(self.layer_wire_bytes.sum())
            dense = float(self.layer_dense_bytes.sum())
            out["wire_bytes_total"] = wire
            out["wire_compression_pct"] = (1.0 - wire / dense) * 100.0
            out["layer_wire_bytes_per_token"] = (
                self.layer_wire_bytes / self.wire_tokens).tolist()
            out["layer_dense_bytes_per_token"] = (
                self.layer_dense_bytes / self.wire_tokens).tolist()
        return out

    def _account_wire(self, req: Request, wire: float, dense: float,
                      layer_wire: np.ndarray, layer_dense: np.ndarray,
                      n_tokens: int) -> None:
        req.wire_bytes_sum += wire
        req.dense_bytes_sum += dense
        if self.layer_wire_bytes is None:
            self.layer_wire_bytes = np.zeros(layer_wire.shape[0], np.float64)
            self.layer_dense_bytes = np.zeros(layer_wire.shape[0], np.float64)
        self.layer_wire_bytes += layer_wire
        self.layer_dense_bytes += layer_dense
        self.wire_tokens += n_tokens

    # -- internals ---------------------------------------------------------

    def _block_table_row(self, req: Request) -> np.ndarray:
        row = np.zeros((self._n_page_steps,), np.int32)
        pages = self.pool.pages_of(req.rid)
        row[:len(pages)] = pages
        return row

    def _prefill_tables(self, req: Request) -> np.ndarray:
        """(D, Pmax) block table for the prefill step: one row per data
        shard, the owning shard's row holding the request's (shard-local)
        pages, every other row all-null (D = 1 without a mesh)."""
        tables = np.zeros((self._data_ways, self._n_page_steps), np.int32)
        tables[self.pool.shard_of(req.rid)] = self._block_table_row(req)
        return tables

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        t = req.sampling.temperature
        if t <= 0.0:
            return int(np.argmax(logits))
        rng = self._rngs.setdefault(
            req.rid, np.random.default_rng(req.sampling.seed + req.rid))
        z = (logits.astype(np.float64) - logits.max()) / t
        p = np.exp(z)
        p /= p.sum()
        return int(rng.choice(len(p), p=p))

    def _emit(self, req: Request, token: int) -> Optional[Tuple[int, int]]:
        now = self._clock()
        if req.t_first is None:
            req.t_first = now
        req.t_last = now
        req.context.append(token)
        req.out_tokens.append(token)
        s = req.sampling
        if (req.n_generated >= s.max_new_tokens or
                (s.stop_token is not None and token == s.stop_token)):
            self.sched.finish(req)
            self._rngs.pop(req.rid, None)
        return (req.rid, token)

    def _run_prefill_chunk(self, req: Request, start: int,
                           n: int) -> List[Tuple[int, int]]:
        toks = np.zeros((1, self._chunk), np.int32)
        toks[0, :n] = req.context[start:start + n]
        logits, self.pool.state, tel = self._prefill_fn(
            self.params, self.pool.state, jnp.asarray(toks),
            jnp.asarray(start, jnp.int32), jnp.asarray(n, jnp.int32),
            jnp.asarray(self._prefill_tables(req)))
        req.sparsity_sum += float(tel["sparsity"]) * n
        req.sparsity_n += n
        layer_wire = np.asarray(tel["layer_wire_bytes"], np.float64)
        layer_dense = np.asarray(tel["layer_dense_bytes"], np.float64)
        self._account_wire(req, float(layer_wire.sum()),
                           float(layer_dense.sum()), layer_wire,
                           layer_dense, n)
        if not self.sched.prefill_advanced(req, n):
            return []
        self.sched.to_running(req)
        ev = self._emit(req, self._sample(req, np.asarray(logits[0])))
        return [ev] if ev else []

    def _run_decode(self, decode: List[Request]) -> List[Tuple[int, int]]:
        B = self._n_slots
        token = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        tables = np.zeros((B, self._n_page_steps), np.int32)
        for req in decode:
            token[req.slot] = req.context[-1]
            pos[req.slot] = len(req.context) - 1
            tables[req.slot] = self._block_table_row(req)
        logits, self.pool.state, tel = self._decode_fn(
            self.params, self.pool.state, jnp.asarray(token),
            jnp.asarray(pos), jnp.asarray(tables))
        logits = np.asarray(logits)
        sparsity = np.asarray(tel["sparsity"])
        layer_wire = np.asarray(tel["layer_wire_bytes"], np.float64)
        layer_dense = np.asarray(tel["layer_dense_bytes"], np.float64)
        events = []
        for req in decode:
            req.sparsity_sum += float(sparsity[req.slot])
            req.sparsity_n += 1
            self._account_wire(
                req, float(layer_wire[:, req.slot].sum()),
                float(layer_dense[:, req.slot].sum()),
                layer_wire[:, req.slot], layer_dense[:, req.slot], 1)
            ev = self._emit(req, self._sample(req, logits[req.slot]))
            if ev:
                events.append(ev)
        return events
