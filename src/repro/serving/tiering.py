"""Device-side KV tier transitions: KV4 <-> KV2 page re-codecs.

The precision ladder's device half. ``serving/kv_pool.py`` owns the host
policy (free lists, tier bookkeeping, demotion candidates — host-only
code under the SPL002 lint contract); this module owns the jitted jnp
work of moving one page between the packed-int4 slab (``k_q``/``v_q``,
two nibbles per byte) and the packed-int2 slab (``k2_q``/``v2_q``, four
two-bit fields per byte, present only when ``PoolConfig.kv2_pages > 0``).

**Demotion** (KV4 -> KV2) clamps each signed int4 nibble to the signed
int2 band ``[KV2_LOW, KV2_HIGH] = [-2, 1]`` and repacks four-per-byte via
the parameterized plane codec (``core.packing.pack_plane`` at
``width=2``); per-token-head f32 scales are copied unchanged. Nibbles
already in band (what ``page_msb_sparsity`` measures) survive exactly, so
a fully in-band page round-trips losslessly; an out-of-band nibble lands
on the nearest band edge with integer error at most 6 (worst case
``-8 -> -2``), i.e. dequantized error at most ``6 * scale`` per element
(see docs/format.md for the resulting logit error bound).

**Promotion** (KV2 -> KV4) sign-extends each two-bit field back to an
int4 nibble and repacks two-per-byte — always exact, since the int2 band
is a subset of the int4 range. demote -> promote is therefore the
identity on in-band pages and a documented clamp elsewhere.

Both ops take the whole device pool state plus traced int32 page ids
(one source, one destination), so a single compilation serves every
page transition of a run. The vacated source page is left as-is: its id
returns to a free list and is fully rewritten before it is ever read
again, exactly like an evicted page.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import pack_plane, unpack_plane

# signed int2 band of a cached int4 nibble (see kv_pool.KV2_LOW/KV2_HIGH;
# duplicated here to keep kv_pool free of device-module import cycles)
KV2_LOW = -2
KV2_HIGH = 1

_PAIRS = (("k_q", "k_s", "k2_q", "k2_s"),
          ("v_q", "v_s", "v2_q", "v2_s"))


def _map_layer_groups(state, fn):
    """Apply ``fn`` to every per-layer leaf dict (the dicts holding the
    ``k_q``/``v_q`` slabs) of the nested pool-state tree."""
    def rec(node):
        if isinstance(node, dict):
            if "k_q" in node:
                return fn(node)
            return {k: rec(v) for k, v in node.items()}
        return node
    return rec(state)


@jax.jit
def demote_page(state, src, dst):
    """Re-encode KV4 page ``src`` into KV2 page ``dst``.

    ``src`` indexes the global page axis of the KV4 slab, ``dst`` the
    KV2 slab; both are traced int32 scalars. Returns the new pool state
    (KV4 source left stale — its id goes back to the free list).
    """
    def grp(lp):
        out = dict(lp)
        for q4, s4, q2, s2 in _PAIRS:
            nib = unpack_plane(lp[q4][:, src], width=4, signed=True)
            nib = jnp.clip(nib, KV2_LOW, KV2_HIGH)
            out[q2] = lp[q2].at[:, dst].set(pack_plane(nib, width=2))
            out[s2] = lp[s2].at[:, dst].set(lp[s4][:, src])
        return out
    return _map_layer_groups(state, grp)


@jax.jit
def promote_page(state, src, dst):
    """Re-encode KV2 page ``src`` back into KV4 page ``dst`` (exact)."""
    def grp(lp):
        out = dict(lp)
        for q4, s4, q2, s2 in _PAIRS:
            nib = unpack_plane(lp[q2][:, src], width=2, signed=True)
            out[q4] = lp[q4].at[:, dst].set(pack_plane(nib, width=4))
            out[s4] = lp[s4].at[:, dst].set(lp[s2][:, src])
        return out
    return _map_layer_groups(state, grp)
