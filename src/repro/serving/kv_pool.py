"""Paged packed-KV4 cache pool (the serving engine's memory subsystem).

The pool owns, per transformer layer, a shared slab of fixed-size pages in
the SPARQLe cache wire format — K/V int4 nibbles packed two-per-byte plus
per-token-head f32 scales — exactly the layout the contiguous decode
kernel already streams (`kernels/kv_attention.py`). Sequences map onto
pages through per-request block tables, so cache capacity is pooled
across all in-flight requests instead of pre-reserved per batch slot:
admission is bounded by *pages*, not by a worst-case max_len rectangle.

Page 0 is reserved as the *null page*: inactive decode slots and padded
prefill tokens write there, which keeps every jitted step shape-static
without masking scatter ops. It is never allocated to a request.

Host-side state (free list, ownership, eviction counters) lives here;
the device-side page arrays are a pytree (`state`) threaded through the
jitted prefill/decode steps by the engine.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import check_paged_support
from repro.models.schema import ParamSpec, Schema
from repro.models.stages import build_stages

NULL_PAGE = 0

# Sub-precision range of a cached int4 nibble, mirroring the LP_LOW/LP_HIGH
# convention of core/sparqle.py: the values representable by the low-order
# 2-bit plane alone. Cache nibbles are SIGNED two's-complement int4
# (quantize_weights is symmetric), so the 2-bit plane is signed too —
# int2 covers [-2, 1]. (The int8 activation range [LP_LOW, LP_HIGH] is
# non-negative only because the LSB4 plane there is unsigned.)
KV2_LOW = -2
KV2_HIGH = 1


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    n_pages: int = 64        # physical pages, including the reserved null page
    page_size: int = 16      # tokens per page


def pool_schema(cfg: ModelConfig, pool: PoolConfig) -> Schema:
    """ParamSpec tree of the device pool state (shardings derivable).

    Mirrors `registry.cache_schema` but replaces the per-sequence
    (batch, max_len) rectangle with the shared (n_pages, page_size) slab.
    """
    check_paged_support(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.hd
    np_, ps = pool.n_pages, pool.page_size

    def layer_pool() -> Schema:
        # logical axes: the page slab shards over "data" (each data shard
        # owns a slab — request-level parallelism), KV heads over "model"
        # (tensor parallelism); see distributed/sharding.DEFAULT_RULES
        return {
            "k_q": ParamSpec((np_, ps, kvh, hd // 2),
                             ("pages", None, "kv_heads", None),
                             jnp.int8, init="zeros"),
            "k_s": ParamSpec((np_, ps, kvh), ("pages", None, "kv_heads"),
                             jnp.float32, init="ones"),
            "v_q": ParamSpec((np_, ps, kvh, hd // 2),
                             ("pages", None, "kv_heads", None),
                             jnp.int8, init="zeros"),
            "v_s": ParamSpec((np_, ps, kvh), ("pages", None, "kv_heads"),
                             jnp.float32, init="ones"),
        }

    def stack(tree: Schema, repeat: int) -> Schema:
        return {k: ParamSpec((repeat,) + v.shape, ("layers",) + v.axes,
                             v.dtype, v.init, v.scale)
                for k, v in tree.items()}

    stages: Schema = {}
    for si, stage in enumerate(build_stages(cfg)):
        stages[f"s{si}"] = {f"p{pi}": stack(layer_pool(), stage.repeat)
                            for pi, _ in enumerate(stage.period)}
    return {"stages": stages}


def init_pool_state(cfg: ModelConfig, pool: PoolConfig):
    """Materialize the device page arrays (zeros; scales one)."""
    def leaf(spec: ParamSpec):
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        return jnp.zeros(spec.shape, spec.dtype)
    return jax.tree_util.tree_map(
        leaf, pool_schema(cfg, pool),
        is_leaf=lambda x: isinstance(x, ParamSpec))


class PagedKVPool:
    """Free-list page allocator over the device pool state.

    ``on_evict(owner, pages)`` fires when :meth:`evict` reclaims a live
    owner's pages (the scheduler's preemption hook).

    **Mesh sharding.** The device state shards along two logical axes
    (``pool_schema``): ``kv_heads`` over the mesh's model axis — every
    model shard holds the same page structure, so ONE host-side free
    list drives all model shards in lock-step and a single block table
    indexes every shard identically (truncate/eviction are pure host
    bookkeeping, no collective) — and ``pages`` over the data axis:
    ``n_shards`` > 1 splits the slab into per-data-shard sub-pools, each
    with its OWN free list, its own reserved null page (local id 0) and
    shard-LOCAL page ids. Block tables then carry local ids, which is
    what lets the paged kernel index its local slab directly inside
    ``shard_map``. An owner's pages all live in one shard (requests pin
    to the data shard of their decode slot). ``n_shards=1`` reproduces
    the original single-pool behavior exactly.
    """

    def __init__(self, cfg: ModelConfig, pool_cfg: PoolConfig,
                 n_shards: int = 1, obs=None):
        """``obs`` (an ``repro.obs.Observability``) registers the pool's
        page-accounting metrics — allocation/release/eviction counters —
        on the owning engine's registry; None (standalone pools, most
        tests) keeps the pool metric-free. Host-side bookkeeping only:
        nothing here touches traced code."""
        if n_shards < 1:
            raise ValueError(n_shards)
        if pool_cfg.n_pages % n_shards:
            raise ValueError(
                f"n_pages={pool_cfg.n_pages} must divide over "
                f"{n_shards} data shards")
        if pool_cfg.n_pages // n_shards < 2:
            raise ValueError("need at least one page beyond the null page "
                             "in every shard")
        self.cfg = cfg
        self.pool_cfg = pool_cfg
        self.n_shards = n_shards
        self.pages_per_shard = pool_cfg.n_pages // n_shards
        self.state = init_pool_state(cfg, pool_cfg)
        self._free = [collections.deque(range(1, self.pages_per_shard))
                      for _ in range(n_shards)]
        self._owned: Dict[object, List[int]] = {}
        self._owner_shard: Dict[object, int] = {}
        self.evictions = 0
        self.on_evict: Optional[Callable[[object, List[int]], None]] = None
        if obs is not None:
            r = obs.registry
            self._m_evict = r.counter(
                "serving_pool_evictions_total",
                "live owners preempted out of their pages", unit="evictions")
            self._m_alloc = r.counter(
                "serving_pool_pages_allocated_total",
                "pages handed to owners", unit="pages")
            self._m_freed = r.counter(
                "serving_pool_pages_released_total",
                "pages returned to the free lists (release/truncate/evict)",
                unit="pages")
        else:
            self._m_evict = self._m_alloc = self._m_freed = None

    # -- capacity ----------------------------------------------------------

    @property
    def page_size(self) -> int:
        return self.pool_cfg.page_size

    @property
    def n_usable_pages(self) -> int:
        # minus one reserved null page per shard
        return self.pool_cfg.n_pages - self.n_shards

    @property
    def usable_pages_per_shard(self) -> int:
        return self.pages_per_shard - 1

    @property
    def num_free(self) -> int:
        return sum(len(f) for f in self._free)

    def free_in_shard(self, shard: int) -> int:
        return len(self._free[shard])

    def pages_of(self, owner) -> List[int]:
        return list(self._owned.get(owner, ()))

    def shard_of(self, owner) -> int:
        """Data shard holding ``owner``'s pages (0 when it holds none)."""
        return self._owner_shard.get(owner, 0)

    # -- allocation --------------------------------------------------------

    def allocate(self, n: int, owner, shard: int = 0) -> Optional[List[int]]:
        """Pop ``n`` pages for ``owner`` from ``shard``'s free list;
        None (no partial grab) if that shard is short. Returned ids are
        shard-local. An owner's pages must all come from one shard."""
        if n < 0:
            raise ValueError(n)
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range")
        if owner in self._owner_shard and self._owner_shard[owner] != shard:
            raise ValueError(
                f"owner {owner!r} already holds pages in shard "
                f"{self._owner_shard[owner]}, cannot allocate in {shard}")
        if n == 0:
            # no phantom ownership entries: a zero-page grab must not make
            # the owner show up in the ownership map (release/evict treat
            # map presence as "holds pages")
            return []
        if n > len(self._free[shard]):
            return None
        pages = [self._free[shard].popleft() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(pages)
        self._owner_shard[owner] = shard
        if self._m_alloc is not None:
            self._m_alloc.inc(n)
        return pages

    def release(self, owner) -> List[int]:
        """Return all of ``owner``'s pages to its shard's free list."""
        pages = self._owned.pop(owner, [])
        shard = self._owner_shard.pop(owner, 0)
        self._free[shard].extend(pages)
        if pages and self._m_freed is not None:
            self._m_freed.inc(len(pages))
        return pages

    def truncate(self, owner, n_tokens: int) -> List[int]:
        """Release ``owner``'s tail pages past a token count.

        Keeps the first ``ceil(n_tokens / page_size)`` pages (a partially
        filled last page is kept whole) and frees the rest — the KV
        rollback primitive for rejected speculative tokens and abandoned
        generation tails, where a full :meth:`release` would throw away
        live context. Page order, ownership of the kept prefix, and the
        eviction counters are untouched; the eviction hook does not fire
        (the owner asked for this — it is not a preemption). Truncating
        to zero tokens removes the ownership entry entirely (no phantom
        owners), and truncating past the held range is a no-op.
        """
        if n_tokens < 0:
            raise ValueError(n_tokens)
        keep = -(-n_tokens // self.page_size)           # ceil div
        pages = self._owned.get(owner)
        if pages is None or len(pages) <= keep:
            return []
        shard = self._owner_shard.get(owner, 0)
        tail = pages[keep:]
        del pages[keep:]
        if not pages:
            del self._owned[owner]
            self._owner_shard.pop(owner, None)
        self._free[shard].extend(tail)
        if self._m_freed is not None:
            self._m_freed.inc(len(tail))
        return tail

    def evict(self, owner) -> List[int]:
        """Preemption hook: reclaim a live owner's pages (and tell them).

        Evicting an owner that holds no pages is a no-op: it neither fires
        the hook nor counts as an eviction (scheduler churn may retry a
        preemption after the victim already released).
        """
        pages = self.pages_of(owner)
        if not pages:
            return []
        if self.on_evict is not None:
            self.on_evict(owner, pages)
        self.evictions += 1
        if self._m_evict is not None:
            self._m_evict.inc()
        return self.release(owner)

    # -- telemetry ---------------------------------------------------------

    def page_msb_sparsity(self, pages: List[int],
                          shard: int = 0) -> np.ndarray:
        """Per-page sub-precision sparsity of the stored int4 nibbles.

        ``pages`` are shard-local ids (as returned by :meth:`allocate`);
        ``shard`` translates them onto the global page axis of the device
        state (a no-op for an unsharded pool).

        The 4-bit analogue of the paper's MSB4 criterion: fraction of
        cached K/V nibbles already representable by the low-order 2-bit
        plane alone, i.e. values in [KV2_LOW, KV2_HIGH] = [-2, 1] (the
        nibbles are signed two's-complement, so the range is the signed
        int2 range — ``nib >> 2 == 0`` would arithmetically sign-extend
        and wrongly exclude -2 and -1). This is the headroom a
        sub-precision cache stream would exploit, averaged over K and V
        across every layer.
        """
        if not pages:
            return np.zeros((0,), np.float32)
        idx = jnp.asarray(pages, jnp.int32) + shard * self.pages_per_shard
        tot = None
        cnt = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.state):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if not name.endswith("_q"):
                continue
            sel = leaf[:, idx]                       # (L, n, ps, kvh, hd/2)
            lo = jnp.right_shift(jnp.left_shift(sel, 4), 4)
            hi = jnp.right_shift(sel, 4)
            nib = jnp.stack([lo, hi], -1)
            sub = (nib >= KV2_LOW) & (nib <= KV2_HIGH)
            per_page = jnp.mean(sub.astype(jnp.float32),
                                axis=(0, 2, 3, 4, 5))  # -> (n,)
            tot = per_page if tot is None else tot + per_page
            cnt += 1
        return np.asarray(tot / max(cnt, 1), np.float32)

    def utilization(self) -> float:
        return 1.0 - self.num_free / max(self.n_usable_pages, 1)
