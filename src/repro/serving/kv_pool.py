"""Paged packed-KV4 cache pool (the serving engine's memory subsystem).

The pool owns, per transformer layer, a shared slab of fixed-size pages in
the SPARQLe cache wire format — K/V int4 nibbles packed two-per-byte plus
per-token-head f32 scales — exactly the layout the contiguous decode
kernel already streams (`kernels/kv_attention.py`). Sequences map onto
pages through per-request block tables, so cache capacity is pooled
across all in-flight requests instead of pre-reserved per batch slot:
admission is bounded by *pages*, not by a worst-case max_len rectangle.

Page 0 is reserved as the *null page*: inactive decode slots and padded
prefill tokens write there, which keeps every jitted step shape-static
without masking scatter ops. It is never allocated to a request.

Host-side state (free list, ownership, eviction counters) lives here;
the device-side page arrays are a pytree (`state`) threaded through the
jitted prefill/decode steps by the engine.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import check_paged_support
from repro.models.schema import ParamSpec, Schema
from repro.models.stages import build_stages

NULL_PAGE = 0

# Sub-precision range of a cached int4 nibble, mirroring the LP_LOW/LP_HIGH
# convention of core/sparqle.py: the values representable by the low-order
# 2-bit plane alone. Cache nibbles are SIGNED two's-complement int4
# (quantize_weights is symmetric), so the 2-bit plane is signed too —
# int2 covers [-2, 1]. (The int8 activation range [LP_LOW, LP_HIGH] is
# non-negative only because the LSB4 plane there is unsigned.)
KV2_LOW = -2
KV2_HIGH = 1


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    n_pages: int = 64        # physical pages, including the reserved null page
    page_size: int = 16      # tokens per page
    # -- KV2 precision ladder (0 pages disables it entirely) ---------------
    kv2_pages: int = 0       # KV2-tier pages, including a reserved null page
    demote_min_sparsity: float = 0.75   # page_msb_sparsity floor to demote
    demote_after_steps: int = 4         # engine steps a page must sit cold


def pool_schema(cfg: ModelConfig, pool: PoolConfig) -> Schema:
    """ParamSpec tree of the device pool state (shardings derivable).

    Mirrors `registry.cache_schema` but replaces the per-sequence
    (batch, max_len) rectangle with the shared (n_pages, page_size) slab.
    """
    check_paged_support(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.hd
    np_, ps = pool.n_pages, pool.page_size
    n2 = pool.kv2_pages
    if n2:
        if n2 < 2:
            raise ValueError("kv2_pages must be >= 2 (one usable page "
                             "beyond the reserved KV2 null page)")
        if hd % 4:
            raise ValueError(f"KV2 tier packs 4 fields/byte: head_dim "
                             f"{hd} must be a multiple of 4")

    def layer_pool() -> Schema:
        # logical axes: the page slab shards over "data" (each data shard
        # owns a slab — request-level parallelism), KV heads over "model"
        # (tensor parallelism); see distributed/sharding.DEFAULT_RULES
        leaves = {
            "k_q": ParamSpec((np_, ps, kvh, hd // 2),
                             ("pages", None, "kv_heads", None),
                             jnp.int8, init="zeros"),
            "k_s": ParamSpec((np_, ps, kvh), ("pages", None, "kv_heads"),
                             jnp.float32, init="ones"),
            "v_q": ParamSpec((np_, ps, kvh, hd // 2),
                             ("pages", None, "kv_heads", None),
                             jnp.int8, init="zeros"),
            "v_s": ParamSpec((np_, ps, kvh), ("pages", None, "kv_heads"),
                             jnp.float32, init="ones"),
        }
        if n2:
            # KV2 slab: demoted pages, int2-band nibbles packed four per
            # byte (core.packing.pack_plane width=2) + untouched scales.
            # KV2 page 0 is the tier's own reserved null page.
            leaves.update({
                "k2_q": ParamSpec((n2, ps, kvh, hd // 4),
                                  ("pages", None, "kv_heads", None),
                                  jnp.int8, init="zeros"),
                "k2_s": ParamSpec((n2, ps, kvh),
                                  ("pages", None, "kv_heads"),
                                  jnp.float32, init="ones"),
                "v2_q": ParamSpec((n2, ps, kvh, hd // 4),
                                  ("pages", None, "kv_heads", None),
                                  jnp.int8, init="zeros"),
                "v2_s": ParamSpec((n2, ps, kvh),
                                  ("pages", None, "kv_heads"),
                                  jnp.float32, init="ones"),
            })
        return leaves

    def stack(tree: Schema, repeat: int) -> Schema:
        return {k: ParamSpec((repeat,) + v.shape, ("layers",) + v.axes,
                             v.dtype, v.init, v.scale)
                for k, v in tree.items()}

    stages: Schema = {}
    for si, stage in enumerate(build_stages(cfg)):
        stages[f"s{si}"] = {f"p{pi}": stack(layer_pool(), stage.repeat)
                            for pi, _ in enumerate(stage.period)}
    return {"stages": stages}


def init_pool_state(cfg: ModelConfig, pool: PoolConfig):
    """Materialize the device page arrays (zeros; scales one)."""
    def leaf(spec: ParamSpec):
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        return jnp.zeros(spec.shape, spec.dtype)
    return jax.tree_util.tree_map(
        leaf, pool_schema(cfg, pool),
        is_leaf=lambda x: isinstance(x, ParamSpec))


class PagedKVPool:
    """Free-list page allocator over the device pool state.

    ``on_evict(owner, pages)`` fires when :meth:`evict` reclaims a live
    owner's pages (the scheduler's preemption hook).

    **Mesh sharding.** The device state shards along two logical axes
    (``pool_schema``): ``kv_heads`` over the mesh's model axis — every
    model shard holds the same page structure, so ONE host-side free
    list drives all model shards in lock-step and a single block table
    indexes every shard identically (truncate/eviction are pure host
    bookkeeping, no collective) — and ``pages`` over the data axis:
    ``n_shards`` > 1 splits the slab into per-data-shard sub-pools, each
    with its OWN free list, its own reserved null page (local id 0) and
    shard-LOCAL page ids. Block tables then carry local ids, which is
    what lets the paged kernel index its local slab directly inside
    ``shard_map``. An owner's pages all live in one shard (requests pin
    to the data shard of their decode slot). ``n_shards=1`` reproduces
    the original single-pool behavior exactly.
    """

    def __init__(self, cfg: ModelConfig, pool_cfg: PoolConfig,
                 n_shards: int = 1, obs=None):
        """``obs`` (an ``repro.obs.Observability``) registers the pool's
        page-accounting metrics — allocation/release/eviction counters —
        on the owning engine's registry; None (standalone pools, most
        tests) keeps the pool metric-free. Host-side bookkeeping only:
        nothing here touches traced code."""
        if n_shards < 1:
            raise ValueError(n_shards)
        if pool_cfg.n_pages % n_shards:
            raise ValueError(
                f"n_pages={pool_cfg.n_pages} must divide over "
                f"{n_shards} data shards")
        if pool_cfg.n_pages // n_shards < 2:
            raise ValueError("need at least one page beyond the null page "
                             "in every shard")
        if pool_cfg.kv2_pages and n_shards > 1:
            raise NotImplementedError(
                "the KV2 precision ladder supports unsharded pools only "
                "(kv2_pages > 0 with a data mesh is not wired up)")
        self.cfg = cfg
        self.pool_cfg = pool_cfg
        self.n_shards = n_shards
        self.pages_per_shard = pool_cfg.n_pages // n_shards
        self.state = init_pool_state(cfg, pool_cfg)
        self._free = [collections.deque(range(1, self.pages_per_shard))
                      for _ in range(n_shards)]
        self._owned: Dict[object, List[int]] = {}
        self._owner_shard: Dict[object, int] = {}
        self.evictions = 0
        self.on_evict: Optional[Callable[[object, List[int]], None]] = None
        # -- KV2 tier bookkeeping (all empty/no-op when kv2_pages == 0) ----
        # _tier[owner][i] is the tier (0=KV4, 1=KV2) of _owned[owner][i];
        # tier-1 entries in _owned hold KV2-slab page ids. _stamp is the
        # pool-clock value of each page's last write (demotion coldness);
        # _spars caches each cold page's measured msb sparsity (pages are
        # immutable once the write frontier moves past, so one device
        # evaluation per page suffices).
        self.clock = 0
        self._free_kv2: collections.deque = collections.deque(
            range(1, pool_cfg.kv2_pages)) if pool_cfg.kv2_pages else \
            collections.deque()
        self._tier: Dict[object, List[int]] = {}
        self._stamp: Dict[object, List[int]] = {}
        self._spars: Dict[object, List[Optional[float]]] = {}
        self.demotions = 0
        self.promotions = 0
        self.kv_bytes_reclaimed = 0
        self._owner_demotions: Dict[object, int] = {}
        self._owner_promotions: Dict[object, int] = {}
        # owners whose pages may be demoted. The engine refreshes this
        # every step with the decode batch: prefill/verify attention read
        # the pool through a tier-UNAWARE dense gather, so a demoted page
        # under a mid-prefill (or waiting, or draft-window) owner would
        # be read as garbage. Only owners whose every read goes through
        # the tiered decode kernel are safe to demote.
        self._demotable: set = set()
        self._page_bytes = {0: 0, 1: 0}
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.state):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            tier = 1 if name.startswith(("k2_", "v2_")) else 0
            # leaf dims: (layers, pages, page_size, ...); bytes per page
            self._page_bytes[tier] += leaf.nbytes // leaf.shape[1]
        if obs is not None:
            r = obs.registry
            self._m_evict = r.counter(
                "serving_pool_evictions_total",
                "live owners preempted out of their pages", unit="evictions")
            self._m_alloc = r.counter(
                "serving_pool_pages_allocated_total",
                "pages handed to owners", unit="pages")
            self._m_freed = r.counter(
                "serving_pool_pages_released_total",
                "pages returned to the free lists (release/truncate/evict)",
                unit="pages")
            self._m_demote = r.counter(
                "serving_pool_demotions_total",
                "pages re-encoded down the ladder (KV4 -> KV2)",
                unit="pages")
            self._m_promote = r.counter(
                "serving_pool_promotions_total",
                "demoted pages re-encoded back up (KV2 -> KV4) on touch",
                unit="pages")
            self._m_reclaimed = r.counter(
                "serving_pool_kv_bytes_reclaimed_total",
                "KV HBM bytes freed by demotion events (cumulative; "
                "promotions do not subtract)", unit="bytes")
        else:
            self._m_evict = self._m_alloc = self._m_freed = None
            self._m_demote = self._m_promote = self._m_reclaimed = None

    # -- capacity ----------------------------------------------------------

    @property
    def page_size(self) -> int:
        return self.pool_cfg.page_size

    @property
    def n_usable_pages(self) -> int:
        # minus one reserved null page per shard
        return self.pool_cfg.n_pages - self.n_shards

    @property
    def usable_pages_per_shard(self) -> int:
        return self.pages_per_shard - 1

    @property
    def num_free(self) -> int:
        return sum(len(f) for f in self._free)

    def free_in_shard(self, shard: int) -> int:
        return len(self._free[shard])

    def pages_of(self, owner) -> List[int]:
        return list(self._owned.get(owner, ()))

    def shard_of(self, owner) -> int:
        """Data shard holding ``owner``'s pages (0 when it holds none)."""
        return self._owner_shard.get(owner, 0)

    # -- allocation --------------------------------------------------------

    def allocate(self, n: int, owner, shard: int = 0) -> Optional[List[int]]:
        """Pop ``n`` pages for ``owner`` from ``shard``'s free list;
        None (no partial grab) if that shard is short. Returned ids are
        shard-local. An owner's pages must all come from one shard."""
        if n < 0:
            raise ValueError(n)
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range")
        if owner in self._owner_shard and self._owner_shard[owner] != shard:
            raise ValueError(
                f"owner {owner!r} already holds pages in shard "
                f"{self._owner_shard[owner]}, cannot allocate in {shard}")
        if n == 0:
            # no phantom ownership entries: a zero-page grab must not make
            # the owner show up in the ownership map (release/evict treat
            # map presence as "holds pages")
            return []
        if n > len(self._free[shard]):
            return None
        pages = [self._free[shard].popleft() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(pages)
        self._owner_shard[owner] = shard
        self._tier.setdefault(owner, []).extend([0] * n)
        self._stamp.setdefault(owner, []).extend([self.clock] * n)
        self._spars.setdefault(owner, []).extend([None] * n)
        if self._m_alloc is not None:
            self._m_alloc.inc(n)
        return pages

    def release(self, owner) -> List[int]:
        """Return all of ``owner``'s pages to their tiers' free lists."""
        pages = self._owned.pop(owner, [])
        tiers = self._tier.pop(owner, [0] * len(pages))
        self._stamp.pop(owner, None)
        self._spars.pop(owner, None)
        self._demotable.discard(owner)
        shard = self._owner_shard.pop(owner, 0)
        for p, t in zip(pages, tiers):
            (self._free_kv2 if t else self._free[shard]).append(p)
        if pages and self._m_freed is not None:
            self._m_freed.inc(len(pages))
        return pages

    def truncate(self, owner, n_tokens: int) -> List[int]:
        """Release ``owner``'s tail pages past a token count.

        Keeps the first ``ceil(n_tokens / page_size)`` pages (a partially
        filled last page is kept whole) and frees the rest — the KV
        rollback primitive for rejected speculative tokens and abandoned
        generation tails, where a full :meth:`release` would throw away
        live context. Page order, ownership of the kept prefix, and the
        eviction counters are untouched; the eviction hook does not fire
        (the owner asked for this — it is not a preemption). Truncating
        to zero tokens removes the ownership entry entirely (no phantom
        owners), and truncating past the held range is a no-op.
        """
        if n_tokens < 0:
            raise ValueError(n_tokens)
        keep = -(-n_tokens // self.page_size)           # ceil div
        pages = self._owned.get(owner)
        if pages is None or len(pages) <= keep:
            return []
        shard = self._owner_shard.get(owner, 0)
        tail = pages[keep:]
        tail_tiers = self._tier[owner][keep:]
        del pages[keep:]
        del self._tier[owner][keep:]
        del self._stamp[owner][keep:]
        del self._spars[owner][keep:]
        if not pages:
            del self._owned[owner]
            self._owner_shard.pop(owner, None)
            for m in (self._tier, self._stamp, self._spars):
                m.pop(owner, None)
        for p, t in zip(tail, tail_tiers):
            (self._free_kv2 if t else self._free[shard]).append(p)
        if self._m_freed is not None:
            self._m_freed.inc(len(tail))
        return tail

    def evict(self, owner) -> List[int]:
        """Preemption hook: reclaim a live owner's pages (and tell them).

        Evicting an owner that holds no pages is a no-op: it neither fires
        the hook nor counts as an eviction (scheduler churn may retry a
        preemption after the victim already released).
        """
        pages = self.pages_of(owner)
        if not pages:
            return []
        if self.on_evict is not None:
            self.on_evict(owner, pages)
        self.evictions += 1
        if self._m_evict is not None:
            self._m_evict.inc()
        return self.release(owner)

    # -- KV2 precision ladder ---------------------------------------------

    @property
    def kv2_armed(self) -> bool:
        return self.pool_cfg.kv2_pages > 0

    @property
    def kv2_free(self) -> int:
        return len(self._free_kv2)

    @property
    def kv2_used(self) -> int:
        return (self.pool_cfg.kv2_pages - 1 - len(self._free_kv2)
                if self.kv2_armed else 0)

    def tiers_of(self, owner) -> List[int]:
        """Per-page tier (0=KV4, 1=KV2) parallel to :meth:`pages_of`."""
        return list(self._tier.get(owner, ()))

    def tier_stats_of(self, owner) -> Dict[str, int]:
        """Cumulative ladder transitions of ``owner``'s pages over its
        whole lifetime (survives release/preemption — the counters are
        never reset, matching the other per-request counters)."""
        return {"demotions": self._owner_demotions.get(owner, 0),
                "promotions": self._owner_promotions.get(owner, 0)}

    def kv_bytes_saved(self) -> int:
        """KV HBM bytes currently freed by demotion: held KV2 pages
        priced at the KV4 rate minus the KV2 rate they actually occupy."""
        held_kv2 = sum(sum(t) for t in self._tier.values())
        return held_kv2 * (self._page_bytes[0] - self._page_bytes[1])

    def kv_bytes_held(self) -> int:
        """KV HBM bytes of all held pages at their current tiers."""
        total = 0
        for owner, pages in self._owned.items():
            for t in self._tier[owner]:
                total += self._page_bytes[t]
        return total

    def tick(self) -> None:
        """Advance the demotion coldness clock (one engine step)."""
        self.clock += 1

    def set_demotable(self, owners) -> None:
        """Declare the owners whose pages demotion may touch this step.

        Only these owners' pages are demotion candidates (for both the
        cold sweep and the pressure rung): everyone else — mid-prefill
        prompts, speculative draft windows — is read through tier-unaware
        gathers and must stay fully KV4. The engine calls this each step
        with the decode batch; it replaces the previous set."""
        self._demotable = set(owners)

    def touch(self, owner, lo: int, hi: int) -> None:
        """Mark ``owner``'s page indices ``[lo, hi]`` as about to be
        written: stamps the coldness clock, invalidates cached sparsity,
        and promotes any demoted page back to KV4 (promotion-on-touch —
        writes always land in the KV4 slab). Call BEFORE the jitted step
        whose writes cover the range. Out-of-range indices ignore."""
        pages = self._owned.get(owner)
        if not pages:
            return
        for i in range(max(lo, 0), min(hi, len(pages) - 1) + 1):
            if self._tier[owner][i]:
                if not self.promote(owner, i):
                    raise RuntimeError(
                        f"cannot promote page {i} of {owner!r}: KV4 "
                        f"shard {self._owner_shard[owner]} exhausted")
            self._stamp[owner][i] = self.clock
            self._spars[owner][i] = None

    def demote(self, owner, idx: int) -> bool:
        """Re-encode ``owner``'s ``idx``-th page KV4 -> KV2 (False when
        the KV2 slab is full or the page is already demoted)."""
        if not self.kv2_armed or self._tier[owner][idx]:
            return False
        if not self._free_kv2:
            return False
        from repro.serving import tiering
        shard = self._owner_shard[owner]
        src = self._owned[owner][idx] + shard * self.pages_per_shard
        dst = self._free_kv2.popleft()
        self.state = tiering.demote_page(self.state, src, dst)
        self._free[shard].append(self._owned[owner][idx])
        self._owned[owner][idx] = dst
        self._tier[owner][idx] = 1
        self.demotions += 1
        self._owner_demotions[owner] = \
            self._owner_demotions.get(owner, 0) + 1
        saved = self._page_bytes[0] - self._page_bytes[1]
        self.kv_bytes_reclaimed += saved
        if self._m_demote is not None:
            self._m_demote.inc()
            self._m_reclaimed.inc(saved)
        return True

    def promote(self, owner, idx: int) -> bool:
        """Re-encode ``owner``'s ``idx``-th page KV2 -> KV4 (exact;
        False when the owner's KV4 shard has no free page)."""
        if not self._tier[owner][idx]:
            return True
        shard = self._owner_shard[owner]
        if not self._free[shard]:
            return False
        from repro.serving import tiering
        src = self._owned[owner][idx]
        dst = self._free[shard].popleft()
        self.state = tiering.promote_page(
            self.state, src, dst + shard * self.pages_per_shard)
        self._free_kv2.append(src)
        self._owned[owner][idx] = dst
        self._tier[owner][idx] = 0
        self.promotions += 1
        self._owner_promotions[owner] = \
            self._owner_promotions.get(owner, 0) + 1
        if self._m_promote is not None:
            self._m_promote.inc()
        return True

    def _demote_candidates(self, shard: Optional[int], min_age: int):
        """(stamp, owner, idx) of demotable pages, coldest first: tier-0,
        owner in the :meth:`set_demotable` set, at least ``min_age``
        clock ticks since last write, and never an owner's final
        (write-frontier) page."""
        out = []
        for owner, pages in self._owned.items():
            if owner not in self._demotable:
                continue
            if shard is not None and self._owner_shard[owner] != shard:
                continue
            for i in range(len(pages) - 1):        # frontier page excluded
                if self._tier[owner][i]:
                    continue
                if self.clock - self._stamp[owner][i] >= min_age:
                    out.append((self._stamp[owner][i], owner, i))
        out.sort(key=lambda c: c[0])
        return out

    def _page_sparsity(self, owner, idx: int) -> float:
        cached = self._spars[owner][idx]
        if cached is None:
            cached = float(self.page_msb_sparsity(
                [self._owned[owner][idx]], self._owner_shard[owner])[0])
            self._spars[owner][idx] = cached
        return cached

    def demote_cold(self, max_pages: Optional[int] = None) -> int:
        """Background demotion sweep (the engine calls this every step):
        demote cold pages — untouched for ``demote_after_steps`` ticks —
        whose measured ``page_msb_sparsity`` clears
        ``demote_min_sparsity``, coldest first, bounded by KV2 slab
        occupancy (and ``max_pages`` when given). Returns pages demoted.
        """
        if not self.kv2_armed:
            return 0
        done = 0
        for _, owner, i in self._demote_candidates(
                None, self.pool_cfg.demote_after_steps):
            if not self._free_kv2 or (max_pages is not None
                                      and done >= max_pages):
                break
            if self.pool_cfg.demote_min_sparsity > 0.0 and \
                    self._page_sparsity(owner, i) < \
                    self.pool_cfg.demote_min_sparsity:
                continue
            if self.demote(owner, i):
                done += 1
        return done

    def demote_for_pressure(self, shard: int, n: int = 1) -> int:
        """Ladder rung between "no free page" and preemption: demote up
        to ``n`` of ``shard``'s coldest non-frontier KV4 pages regardless
        of their sparsity (the clamp error stays bounded — docs/format.md)
        to free KV4 pages without evicting anyone. Returns pages freed."""
        if not self.kv2_armed:
            return 0
        done = 0
        for _, owner, i in self._demote_candidates(shard, 1):
            if done >= n or not self._free_kv2:
                break
            if self.demote(owner, i):
                done += 1
        return done

    # -- telemetry ---------------------------------------------------------

    def page_msb_sparsity(self, pages: List[int],
                          shard: int = 0) -> np.ndarray:
        """Per-page sub-precision sparsity of the stored int4 nibbles.

        ``pages`` are shard-local ids (as returned by :meth:`allocate`);
        ``shard`` translates them onto the global page axis of the device
        state (a no-op for an unsharded pool).

        The 4-bit analogue of the paper's MSB4 criterion: fraction of
        cached K/V nibbles already representable by the low-order 2-bit
        plane alone, i.e. values in [KV2_LOW, KV2_HIGH] = [-2, 1] (the
        nibbles are signed two's-complement, so the range is the signed
        int2 range — ``nib >> 2 == 0`` would arithmetically sign-extend
        and wrongly exclude -2 and -1). This is the headroom a
        sub-precision cache stream would exploit, averaged over K and V
        across every layer.
        """
        if not pages:
            return np.zeros((0,), np.float32)
        idx = jnp.asarray(pages, jnp.int32) + shard * self.pages_per_shard
        tot = None
        cnt = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.state):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name not in ("k_q", "v_q"):    # KV2 slab has its own ids
                continue
            sel = leaf[:, idx]                       # (L, n, ps, kvh, hd/2)
            lo = jnp.right_shift(jnp.left_shift(sel, 4), 4)
            hi = jnp.right_shift(sel, 4)
            nib = jnp.stack([lo, hi], -1)
            sub = (nib >= KV2_LOW) & (nib <= KV2_HIGH)
            per_page = jnp.mean(sub.astype(jnp.float32),
                                axis=(0, 2, 3, 4, 5))  # -> (n,)
            tot = per_page if tot is None else tot + per_page
            cnt += 1
        return np.asarray(tot / max(cnt, 1), np.float32)

    def utilization(self) -> float:
        return 1.0 - self.num_free / max(self.n_usable_pages, 1)
