"""Continuous-batching serving over a paged packed-KV4 cache pool.

  * kv_pool    — paged pool in the SPARQLe cache wire format (free-list
                 allocation, null page, eviction hooks, MSB telemetry)
  * scheduler  — FCFS continuous batching: token budget, chunked prefill,
                 decode-slot backfill, recompute-style preemption
  * engine     — the serving loop: submit() / stream() / run() over two
                 shape-static jitted steps (see docs/serving.md)
"""
from repro.serving.engine import Engine
from repro.serving.kv_pool import PagedKVPool, PoolConfig
from repro.serving.scheduler import (Request, SamplingParams, Scheduler,
                                     SchedulerConfig)

__all__ = ["Engine", "PagedKVPool", "PoolConfig", "Request",
           "SamplingParams", "Scheduler", "SchedulerConfig"]
