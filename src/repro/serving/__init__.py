"""Continuous-batching serving over a paged packed-KV4 cache pool.

  * kv_pool     — paged pool in the SPARQLe cache wire format (free-list
                  allocation, null page, eviction hooks, MSB telemetry,
                  tail truncation for speculative rollback)
  * scheduler   — FCFS continuous batching: token budget, chunked
                  prefill, decode-slot backfill, recompute-style
                  preemption, draft-window budget/lookahead accounting
  * engine      — the serving loop: submit() / stream() / run() over two
                  shape-static jitted steps (see docs/serving.md)
  * spec_decode — self-speculative decoding: γ LSB4-only draft steps +
                  one batched full-precision verify per cycle

Every engine owns (or is handed) a ``repro.obs.Observability`` bundle —
metrics registry + span tracer — that the pool, scheduler and step loop
feed host-side (docs/observability.md).
"""
from repro.obs import Observability
from repro.serving.engine import Engine
from repro.serving.kv_pool import PagedKVPool, PoolConfig
from repro.serving.scheduler import (Request, SamplingParams, Scheduler,
                                     SchedulerConfig)
from repro.serving.spec_decode import SpecConfig, SpeculativeEngine

__all__ = ["Engine", "Observability", "PagedKVPool", "PoolConfig",
           "Request", "SamplingParams", "Scheduler", "SchedulerConfig",
           "SpecConfig", "SpeculativeEngine"]
