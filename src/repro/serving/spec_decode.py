"""Self-speculative decoding: LSB4-only drafting, batched full verification.

SPARQLe's hybrid format contains a free draft model (paper §3.3): the
dense LSB4 pass costs 1 compute round while the full LSB+MSB path costs
1 + (1 - s) rounds, so a forward with the sparse MSB pass *statically
elided* (``qlinear.msb_skip_scope``) is a cheap, always-resident
approximation of the full model — same weights, same KV cache, no second
network. This module turns that into self-speculative decoding:

  1. **draft** — γ decode steps through the LSB4-only jitted step
     (``steps.make_engine_decode(msb_skip=True, with_telemetry=False)``).
     Each step writes the draft's *approximate* K/V into the request's
     pages and proposes the next token (greedy at temperature 0, sampled
     from the draft distribution otherwise).
  2. **verify** — ONE full-precision batched step
     (``steps.make_engine_verify_window``) scores the whole (γ+1)-token
     window for every decode slot at once, overwriting the draft K/V
     with full-precision values. The multi-token paged attention kernel
     is bit-exact against a loop of single-token decodes, so at
     temperature 0 the verified stream is byte-identical to the
     non-speculative engine's greedy tokens.
  3. **accept** — greedy exact-match acceptance at temperature 0
     (emit full-precision argmax tokens while they match the draft, then
     the correction/bonus token); standard rejection sampling otherwise
     (accept draft d with prob min(1, p_full(d)/p_draft(d)); on reject,
     sample the residual max(0, p_full - p_draft)). Every cycle emits
     between 1 and γ+1 tokens.
  4. **rollback** — ``PagedKVPool.truncate`` releases tail pages past
     the accepted context; rejected K/V left mid-page sits beyond the
     causal mask until overwritten.

Budget/memory accounting: a speculative decode slot burns 2γ+1 compute
tokens per scheduler step and writes K/V up to γ positions ahead, which
``SchedulerConfig.decode_tokens_per_slot`` / ``decode_lookahead`` feed
into the scheduler's token budget, page growth and admission checks.

Telemetry semantics: the γ draft steps run the LSB4-only jitted step
compiled ``with_telemetry=False`` — they carry NO wire-byte or sparsity
telemetry, by design (telemetry reductions would erase most of the
draft's latency win). Only the verify window's γ+1 tokens enter the
wire-byte accounting, so ``Request.wire_tokens`` counts telemetered
tokens and ``Request.draft_tokens`` counts the untelemetered draft
compute tokens separately. Folding drafts into the wire denominator
would understate bytes/token by up to (2γ+1)/(γ+1)× — keep them apart.

    eng = SpeculativeEngine(cfg, qparams, spec=SpecConfig(gamma=3))
    h = eng.submit(prompt, SamplingParams(max_new_tokens=32))
    eng.run()
    h.stats()["spec_acceptance_rate"], h.stats()["spec_tokens_per_step"]
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.engine import Engine
from repro.serving.kv_pool import PoolConfig
from repro.serving.scheduler import Request, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    gamma: int = 2                   # draft tokens per verify cycle

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")


def _softmax(logits: np.ndarray, temperature: float) -> np.ndarray:
    z = (logits.astype(np.float64) - logits.max()) / temperature
    p = np.exp(z)
    return p / p.sum()


class SpeculativeEngine(Engine):
    """Continuous-batching engine with self-speculative decode steps.

    Drop-in for :class:`Engine`: same submit/stream/run API, same paged
    pool, same chunked prefill. Only the decode path changes — γ LSB-only
    draft steps followed by one batched full-precision verify instead of
    one full decode per token. With ``mode='sparqle'`` params the draft
    is genuinely sub-precision (acceptance < 1); with ``mode='dense'``
    params the draft equals the target and speculation degenerates to
    always-accept.
    """

    def __init__(self, cfg: ModelConfig, params,
                 pool_config: Optional[PoolConfig] = None,
                 sched_config: Optional[SchedulerConfig] = None,
                 spec: SpecConfig = SpecConfig(),
                 clock=time.monotonic, mesh=None, obs=None, slos=None):
        from repro.launch import steps as S
        self.spec = spec
        g = spec.gamma
        if pool_config is not None and pool_config.kv2_pages:
            # the draft and verify steps read the pool through
            # tier-unaware gathers, so a demoted page would be read as
            # garbage mid-window; the ladder is base-engine-only for now
            raise NotImplementedError(
                "the KV2 precision ladder (kv2_pages > 0) is not "
                "supported by the speculative engine")
        sched_config = dataclasses.replace(
            sched_config or SchedulerConfig(),
            decode_tokens_per_slot=2 * g + 1,   # γ draft + (γ+1) verify
            decode_lookahead=g)
        super().__init__(cfg, params, pool_config=pool_config,
                         sched_config=sched_config, clock=clock, mesh=mesh,
                         obs=obs, slos=slos)
        # draft/verify share the engine's mesh layout (self.mesh is None
        # when no multi-device mesh was given): the LSB4-only draft and
        # the batched verify run inside the same shard_map partitioning
        # as the base decode step, so a sharded speculative stream is
        # bit-exact vs the sharded (and single-device) base engine
        self._draft_fn = jax.jit(
            S.make_engine_decode(cfg, msb_skip=True, with_telemetry=False,
                                 mesh=self.mesh,
                                 param_specs=self._param_specs,
                                 pool_specs=self._pool_specs),
            donate_argnums=(1,))
        self._verify_fn = jax.jit(
            S.make_engine_verify_window(cfg, mesh=self.mesh,
                                        param_specs=self._param_specs,
                                        pool_specs=self._pool_specs),
            donate_argnums=(1,))
        # engine-level speculative counters (per-request ones live on
        # Request; these survive request handles going out of scope)
        self.draft_proposed_total = 0
        self.draft_accepted_total = 0
        self.spec_steps_total = 0
        self.spec_emitted_total = 0
        r = self.obs.registry
        self._m_spec_proposed = r.counter(
            "serving_spec_draft_proposed_total", "draft tokens the "
            "verifier examined", unit="tokens")
        self._m_spec_accepted = r.counter(
            "serving_spec_draft_accepted_total", "examined draft tokens "
            "the full-precision model accepted", unit="tokens")
        self._m_spec_cycles = r.counter(
            "serving_spec_cycles_total", "draft+verify cycles run (one "
            "per decode slot per engine step)", unit="steps")
        self._m_spec_emitted = r.counter(
            "serving_spec_tokens_emitted_total", "tokens emitted by "
            "accept/correct/bonus across all cycles", unit="tokens")

    # -- performance attribution ------------------------------------------

    def attribute_steps(self, hw=None):
        """Extend base attribution with the speculative steps.

        The ``draft`` phase wall-time (``serving_step_seconds{phase=
        draft}``) wraps the whole γ-step host loop, so the draft cost is
        attributed with ``calls_per_step=γ`` — one timed phase executes
        the LSB4-only decode program γ times — keeping the runtime
        roofline join apples-to-apples. ``verify`` is one (γ+1)-token
        window step per phase.
        """
        attr = super().attribute_steps(hw=hw)
        g = self.spec.gamma
        sds = jax.ShapeDtypeStruct
        params_a, pool_a = self._attr_abstract_args()
        if "draft" not in attr.phases():
            attr.attribute(
                "draft", self._draft_fn,
                (params_a, pool_a, sds((self._n_slots,), jnp.int32),
                 sds((self._n_slots,), jnp.int32),
                 sds((self._n_slots, self._n_page_steps), jnp.int32)),
                tokens_per_step=self._n_slots * g, calls_per_step=g,
                predict_seconds=self._spec_predictor("draft"))
        if "verify" not in attr.phases():
            attr.attribute(
                "verify", self._verify_fn,
                (params_a, pool_a, sds((self._n_slots, g + 1), jnp.int32),
                 sds((self._n_slots,), jnp.int32),
                 sds((self._n_slots, self._n_page_steps), jnp.int32)),
                tokens_per_step=self._n_slots * (g + 1),
                predict_seconds=self._spec_predictor("verify"))
        return attr

    def _spec_predictor(self, phase: str):
        """sparsity -> predicted seconds per TIMED phase: γ LSB4-only
        decode rounds for draft, one (γ+1)-token window for verify."""
        from repro.core import costmodel as CM
        shape = self._costmodel_shape()
        hw = self._attr.hw
        g = self.spec.gamma
        seq_for_attn = self._n_page_steps * self.pool.page_size
        lsb_only = phase == "draft"
        m_tokens = self._n_slots if lsb_only else self._n_slots * (g + 1)
        calls = g if lsb_only else 1

        def predict(sparsity: float) -> float:
            layers = CM.lm_linear_layers(
                shape, m_tokens, sparsity, seq_for_attn=seq_for_attn,
                decode=True)
            cost = CM.phase_cost(layers, hw, sparqle=True,
                                 lsb_only=lsb_only)
            return calls * cost.cycles / (hw.freq_ghz * 1e9)
        return predict

    # -- decode path -------------------------------------------------------

    def _run_decode(self, decode: List[Request]) -> List[Tuple[int, int]]:
        B, g = self._n_slots, self.spec.gamma
        token = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        tables = np.zeros((B, self._n_page_steps), np.int32)
        for req in decode:
            token[req.slot] = req.context[-1]
            pos[req.slot] = len(req.context) - 1
            tables[req.slot] = self._block_table_row(req)

        # ---- draft: γ LSB4-only steps, token fed forward host-side ----
        window = np.zeros((B, g + 1), np.int32)
        window[:, 0] = token
        jpos = jnp.asarray(pos)
        jtables = jnp.asarray(tables)
        cur = jnp.asarray(token)
        dlogs = []
        with self.obs.tracer.span("spec_draft", slots=len(decode),
                                  gamma=g):
            with self._m_step_lat.time(phase="draft"):
                for i in range(g):
                    dlg, self.pool.state, _ = self._draft_fn(
                        self.params, self.pool.state, cur,
                        jpos + jnp.int32(i), jtables)
                    dlg = np.asarray(dlg)
                    dlogs.append(dlg)
                    nxt = np.zeros((B,), np.int32)
                    for req in decode:
                        nxt[req.slot] = self._sample(req, dlg[req.slot])
                    window[:, i + 1] = nxt
                    cur = jnp.asarray(nxt)
        draft_logits = np.stack(dlogs, axis=1)          # (B, γ, V)
        self._m_tokens.inc(len(decode) * g, phase="draft")

        # ---- verify: one full-precision batched window step ----
        with self.obs.tracer.span("spec_verify", slots=len(decode),
                                  window=g + 1):
            with self._m_step_lat.time(phase="verify"):
                vlg, self.pool.state, tel = self._verify_fn(
                    self.params, self.pool.state, jnp.asarray(window),
                    jpos, jtables)
                vlg = np.asarray(vlg)                   # (B, γ+1, V)
        self._m_tokens.inc(len(decode) * (g + 1), phase="verify")
        sparsity = np.asarray(tel["sparsity"])
        layer_wire = np.asarray(tel["layer_wire_bytes"], np.float64)
        layer_dense = np.asarray(tel["layer_dense_bytes"], np.float64)
        layer_spars = np.asarray(tel["layer_sparsity"], np.float64)

        events: List[Tuple[int, int]] = []
        for req in decode:
            s = req.slot
            req.sparsity_sum += float(sparsity[s]) * (g + 1)
            req.sparsity_n += g + 1
            # γ draft compute tokens ran telemetry-free (module
            # docstring) — tracked apart from the wire denominator
            req.draft_tokens += g
            self._account_wire(
                req, float(layer_wire[:, s].sum()),
                float(layer_dense[:, s].sum()),
                layer_wire[:, s], layer_dense[:, s],
                layer_spars[:, s] * (g + 1), g + 1)
            events.extend(
                self._accept_and_emit(req, window[s], vlg[s],
                                      draft_logits[s]))
            if not req.done:
                # KV rollback: free tail pages past the accepted context
                # (context[-1]'s own slot is kept — the next cycle writes
                # there first); stale rejected K/V left mid-page sits
                # beyond the causal mask until overwritten
                self.pool.truncate(req.rid, len(req.context))
        return events

    # -- acceptance --------------------------------------------------------

    def _accept_and_emit(self, req: Request, window: np.ndarray,
                         vlogits: np.ndarray, dlogits: np.ndarray
                         ) -> List[Tuple[int, int]]:
        """Walk one request's verified window, emitting accepted tokens.

        ``window`` (γ+1,) — window[0] is the request's last accepted
        token, window[1:] the draft proposals. ``vlogits`` (γ+1, V) —
        full-precision logits after each window token. ``dlogits``
        (γ, V) — the draft logits each proposal was sampled from.
        """
        g = self.spec.gamma
        t = req.sampling.temperature
        events: List[Tuple[int, int]] = []
        emitted = accepted = examined = 0

        if t <= 0.0:
            # greedy exact-match: emit full-precision argmaxes while the
            # draft guessed them; the first mismatch emits the correction
            # (and a fully-accepted window emits the free bonus token)
            for i in range(g + 1):
                if req.done:
                    break
                y = int(np.argmax(vlogits[i]))
                ev = self._emit(req, y)
                if ev:
                    events.append(ev)
                emitted += 1
                if i == g:
                    break
                examined += 1
                if int(window[i + 1]) != y:
                    break
                accepted += 1
        else:
            # rejection sampling: emitted tokens are distributed per the
            # full-precision model regardless of draft quality
            rng = self._rngs.setdefault(
                req.rid,
                np.random.default_rng(req.sampling.seed + req.rid))
            rejected = False
            for i in range(g):
                if req.done:
                    break
                d = int(window[i + 1])
                p_full = _softmax(vlogits[i], t)
                p_draft = _softmax(dlogits[i], t)
                examined += 1
                if rng.random() < min(1.0, p_full[d] /
                                      max(p_draft[d], 1e-300)):
                    ev = self._emit(req, d)
                    if ev:
                        events.append(ev)
                    emitted += 1
                    accepted += 1
                    continue
                res = np.maximum(p_full - p_draft, 0.0)
                tot = res.sum()
                p = res / tot if tot > 0.0 else p_full
                ev = self._emit(req, int(rng.choice(len(p), p=p)))
                if ev:
                    events.append(ev)
                emitted += 1
                rejected = True
                break
            if not rejected and not req.done:
                p_full = _softmax(vlogits[g], t)
                ev = self._emit(req, int(rng.choice(len(p_full),
                                                    p=p_full)))
                if ev:
                    events.append(ev)
                emitted += 1

        # proposed counts only drafts the verifier actually EXAMINED: a
        # request finishing mid-window leaves its tail drafts unjudged,
        # and counting those would deflate the acceptance rate that
        # costmodel.evaluate_speculative takes as alpha
        req.draft_proposed += examined
        req.draft_accepted += accepted
        req.spec_steps += 1
        req.spec_emitted += emitted
        self.draft_proposed_total += examined
        self.draft_accepted_total += accepted
        self.spec_steps_total += 1
        self.spec_emitted_total += emitted
        self._m_spec_proposed.inc(examined)
        self._m_spec_accepted.inc(accepted)
        self._m_spec_cycles.inc()
        self._m_spec_emitted.inc(emitted)
        return events

    # -- telemetry ---------------------------------------------------------

    def aggregate_stats(self) -> dict:
        out = super().aggregate_stats()
        r = self.obs.registry
        proposed = int(r.value("serving_spec_draft_proposed_total"))
        accepted = int(r.value("serving_spec_draft_accepted_total"))
        cycles = int(r.value("serving_spec_cycles_total"))
        emitted = int(r.value("serving_spec_tokens_emitted_total"))
        out["spec_gamma"] = self.spec.gamma
        if proposed:
            out["spec_acceptance_rate"] = accepted / proposed
        if cycles:
            out["spec_tokens_per_step"] = emitted / cycles
        return out
