"""Continuous-batching scheduler: FCFS admission under a token budget.

Every engine step the scheduler emits a :class:`StepPlan`:

  * ``decode``  — the running requests (one token each). Before planning,
    each running request that crosses a page boundary gets one new page;
    if the pool is out of pages the scheduler climbs the eviction ladder:
    first demote the shard's coldest decode-owned page KV4 -> KV2 (when
    the precision ladder is armed; frees a KV4 page without evicting
    anyone), then preempt the *youngest* running request (recompute-style:
    its pages are evicted and it re-enters the waiting queue with its
    generated tokens folded into the prompt).
  * ``prefill`` — FCFS chunks of waiting prompts, bounded by the step's
    remaining token budget, free decode slots, and free pages. Chunked
    prefill lets a long prompt share steps with in-flight decodes instead
    of stalling them.

Decode-batch slots are backfilled every step: a request finishing at step
t frees its slot and pages for a waiting request's prefill at step t+1.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import List, Optional, Tuple

from repro.serving.kv_pool import PagedKVPool

WAITING, PREFILL, RUNNING, FINISHED = ("waiting", "prefill", "running",
                                       "finished")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 -> greedy
    seed: int = 0
    stop_token: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_decode_batch: int = 8        # decode slots (jitted batch width)
    token_budget: int = 64           # tokens processed per engine step
    prefill_chunk: int = 32          # tokens per prefill call (jit shape)
    max_pages_per_seq: int = 16      # block-table width (jit shape)
    # speculative decoding (serving/spec_decode.py). A γ-draft request
    # burns 2γ+1 tokens of compute per scheduler step (γ draft + γ+1
    # verify) and writes K/V up to γ positions past its context, so the
    # budget and the page-growth/admission math both account for it:
    decode_tokens_per_slot: int = 1  # compute tokens per decode slot/step
    decode_lookahead: int = 0        # KV positions written past pos (= γ)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    sampling: SamplingParams
    arrival: float
    context: List[int] = dataclasses.field(default_factory=list)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    prefilled: int = 0
    status: str = WAITING
    slot: Optional[int] = None
    # stats
    t_first: Optional[float] = None
    t_last: Optional[float] = None
    sparsity_sum: float = 0.0
    sparsity_n: int = 0
    wire_bytes_sum: float = 0.0      # measured packed-wire activation bytes
    dense_bytes_sum: float = 0.0     # dense int8 baseline for the same acts
    wire_tokens: int = 0             # tokens the wire telemetry covered
    draft_tokens: int = 0            # LSB4-only draft tokens (no telemetry)
    preemptions: int = 0
    # speculative decoding (serving/spec_decode.py)
    draft_proposed: int = 0          # LSB4-only drafts the verifier judged
    draft_accepted: int = 0          # ... of those, accepted
    spec_steps: int = 0              # draft+verify cycles run
    spec_emitted: int = 0            # tokens emitted by those cycles
    # KV2 precision ladder (serving/kv_pool.py): cumulative page tier
    # transitions this request's cache underwent (0/0 when disarmed)
    kv_demotions: int = 0
    kv_promotions: int = 0

    def __post_init__(self):
        if not self.context:
            self.context = list(self.prompt)

    @property
    def n_generated(self) -> int:
        return len(self.out_tokens)

    @property
    def done(self) -> bool:
        return self.status == FINISHED

    def stats(self) -> dict:
        """Per-request serving statistics (NaN where undefined — e.g. a
        request that never emitted a token has no TTFT/TPOT, a request
        with no telemetered steps has no wire accounting).

        Wire-format semantics: ``act_wire_bytes_per_token`` divides the
        measured packed-wire bytes by ``wire_tokens`` — the tokens whose
        activations the telemetry actually covered (prefill chunks,
        full decode steps, and speculative *verify* windows). The γ
        LSB4-only draft steps per speculative cycle run with telemetry
        statically elided (they execute γ times per emitted batch), so
        their tokens are counted separately in ``draft_tokens`` and are
        deliberately EXCLUDED from the wire denominator: mixing them in
        would silently understate bytes/token by up to (2γ+1)/(γ+1)x.
        """
        ttft = (self.t_first - self.arrival
                if self.t_first is not None else float("nan"))
        if self.t_first is not None and self.n_generated > 1:
            tpot = (self.t_last - self.t_first) / (self.n_generated - 1)
        else:
            tpot = float("nan")
        return {
            "ttft_s": ttft,
            "tpot_s": tpot,
            "n_generated": self.n_generated,
            "act_sparsity": (self.sparsity_sum / self.sparsity_n
                             if self.sparsity_n else float("nan")),
            # measured wire-format accounting of this request's
            # inter-layer hidden activation stream (summed over layers
            # and TELEMETERED tokens; see layers.act_wire_telemetry and
            # the docstring above for the speculative-draft exclusion)
            "act_wire_bytes_per_token": (
                self.wire_bytes_sum / self.wire_tokens
                if self.wire_tokens else float("nan")),
            "wire_tokens": self.wire_tokens,
            "draft_tokens": self.draft_tokens,
            "act_wire_compression_pct": (
                (1.0 - self.wire_bytes_sum / self.dense_bytes_sum) * 100.0
                if self.dense_bytes_sum else float("nan")),
            "preemptions": self.preemptions,
            # speculative decoding: fraction of LSB4-only draft tokens the
            # full-precision verifier accepted, and emitted tokens per
            # draft+verify cycle (>= 1: the correction token always lands)
            "spec_acceptance_rate": (
                self.draft_accepted / self.draft_proposed
                if self.draft_proposed else float("nan")),
            "spec_tokens_per_step": (
                self.spec_emitted / self.spec_steps
                if self.spec_steps else float("nan")),
            # KV2 precision ladder: pages of this request's cache demoted
            # to the int2 tier (and promoted back on touch) over its life
            "kv_demotions": self.kv_demotions,
            "kv_promotions": self.kv_promotions,
        }


@dataclasses.dataclass
class StepPlan:
    prefill: List[Tuple[Request, int, int]]   # (request, start, n_tokens)
    decode: List[Request]

    @property
    def empty(self) -> bool:
        return not self.prefill and not self.decode


class Scheduler:
    def __init__(self, pool: PagedKVPool, cfg: SchedulerConfig, obs=None):
        """``obs`` (``repro.obs.Observability``, usually the owning
        engine's) makes the scheduler observable: queue-depth/running-slot
        gauges and admission/preemption counters on the registry, plus
        per-request lifecycle spans (waiting → prefill → decode, with
        preemption gaps as renewed waiting spans) on a per-request tracer
        track — the timeline ``serve.py --trace-out`` exports. All
        host-side; None disables everything."""
        self.pool = pool
        self.cfg = cfg
        self.obs = obs
        if obs is not None:
            r = obs.registry
            self._m_submitted = r.counter(
                "serving_requests_submitted_total", "requests accepted by "
                "submit()", unit="requests")
            self._m_finished = r.counter(
                "serving_requests_finished_total", "requests that reached "
                "FINISHED", unit="requests")
            self._m_preempted = r.counter(
                "serving_preemptions_total", "recompute-style preemptions "
                "(pages evicted, request re-queued)", unit="preemptions")
            self._m_queue = r.gauge(
                "serving_queue_depth", "waiting requests after the last "
                "schedule()", unit="requests")
            self._m_running = r.gauge(
                "serving_running_slots", "decode slots occupied after the "
                "last schedule()", unit="slots")
        self._phase_spans: dict = {}
        self.waiting: collections.deque[Request] = collections.deque()
        self.running: List[Request] = []
        self._free_slots = list(range(cfg.max_decode_batch))
        self._rid = itertools.count()
        # mesh-sharded pool: decode slots partition contiguously over the
        # pool's data shards, and a request's pages are pinned to its
        # slot's shard (shard-local block-table ids; see PagedKVPool).
        # Slots are deliberately handed out in the SAME ascending order
        # as the unsharded scheduler — interleaving across shards would
        # balance page pressure, but the slot index is the stable
        # tie-break of MoE routing (sort by expert, then flat batch
        # index), so diverging slot layouts would break the sharded-vs-
        # single-device stream equivalence contract (docs/sharding.md)
        assert cfg.max_decode_batch % pool.n_shards == 0, (
            cfg.max_decode_batch, pool.n_shards)
        self._slots_per_shard = cfg.max_decode_batch // pool.n_shards

    def _shard(self, req: Request) -> int:
        """Data shard of a request's decode slot (0 for unsharded pools)."""
        if self.pool.n_shards == 1 or req.slot is None:
            return 0
        return req.slot // self._slots_per_shard

    # -- observability -----------------------------------------------------

    def _lifecycle(self, req: Request, phase: Optional[str],
                   **args) -> None:
        """Close the request's open lifecycle span and (unless ``phase``
        is None) open the next one on its per-request trace track. One
        span per request is open at any time, so the exported timeline is
        a gap-free tiling of waiting/prefill/decode phases — a preemption
        shows up as a fresh ``waiting`` span with ``preempted=True``."""
        if self.obs is None:
            return
        tr = self.obs.tracer
        tr.end(self._phase_spans.pop(req.rid, None))
        if phase is not None:
            from repro.obs import REQUEST_TRACK_BASE
            self._phase_spans[req.rid] = tr.begin(
                phase, track=REQUEST_TRACK_BASE + req.rid, rid=req.rid,
                **args)

    # -- intake ------------------------------------------------------------

    def submit(self, prompt: List[int], sampling: SamplingParams,
               arrival: float) -> Request:
        cap = self.cfg.max_pages_per_seq * self.pool.page_size
        # lookahead: a draft window near the end of generation writes K/V
        # up to decode_lookahead positions past the last sampled token,
        # so those slots must exist in the block table too
        need = (len(prompt) + sampling.max_new_tokens
                + self.cfg.decode_lookahead)
        if need > cap:
            raise ValueError(
                f"request needs {need} token slots but the block table "
                f"caps a sequence at {cap} "
                f"(max_pages_per_seq * page_size)")
        if need > self.pool.usable_pages_per_shard * self.pool.page_size:
            raise ValueError(
                f"request needs {need} token slots; every pool shard "
                f"holds only "
                f"{self.pool.usable_pages_per_shard * self.pool.page_size}"
                f" (a request's pages live in one data shard)")
        if not prompt:
            raise ValueError("empty prompt")
        if sampling.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      sampling=sampling, arrival=arrival)
        self.waiting.append(req)
        if self.obs is not None:
            self._m_submitted.inc()
            from repro.obs import REQUEST_TRACK_BASE
            self.obs.tracer.set_track_name(REQUEST_TRACK_BASE + req.rid,
                                           f"request {req.rid}")
            self._lifecycle(req, WAITING)
        return req

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- lifecycle hooks (called by the engine) ----------------------------

    def prefill_advanced(self, req: Request, n: int) -> bool:
        """Account ``n`` prefilled tokens; True when the prompt is done."""
        req.prefilled += n
        return req.prefilled >= len(req.context)

    def to_running(self, req: Request) -> None:
        if req in self.waiting:
            self.waiting.remove(req)
        req.status = RUNNING
        self.running.append(req)
        self._lifecycle(req, "decode", slot=req.slot)

    def finish(self, req: Request) -> None:
        req.status = FINISHED
        ts = self.pool.tier_stats_of(req.rid)
        req.kv_demotions = ts["demotions"]
        req.kv_promotions = ts["promotions"]
        if req in self.running:
            self.running.remove(req)
        if req in self.waiting:
            self.waiting.remove(req)
        if req.slot is not None:
            self._free_slots.append(req.slot)
            req.slot = None
        self.pool.release(req.rid)
        self._lifecycle(req, None)
        if self.obs is not None:
            self._m_finished.inc()
            from repro.obs import REQUEST_TRACK_BASE
            self.obs.tracer.instant("finished",
                                    track=REQUEST_TRACK_BASE + req.rid,
                                    rid=req.rid,
                                    n_generated=req.n_generated)

    def preempt(self, req: Request) -> None:
        """Recompute-style preemption: evict pages, fold generated tokens
        into the prompt, and re-queue at the head of the waiting line."""
        self.pool.evict(req.rid)
        req.preemptions += 1
        req.prefilled = 0
        if req in self.running:
            self.running.remove(req)
        if req in self.waiting:
            self.waiting.remove(req)
        req.status = WAITING
        if self.obs is not None:
            self._m_preempted.inc()
        self._lifecycle(req, WAITING, preempted=True)
        # re-enter in arrival order so FCFS priority survives preemption
        idx = next((i for i, r in enumerate(self.waiting)
                    if (r.arrival, r.rid) > (req.arrival, req.rid)),
                   len(self.waiting))
        self.waiting.insert(idx, req)

    # -- planning ----------------------------------------------------------

    def _pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.pool.page_size)      # ceil div

    def _ensure_decode_page(self, req: Request) -> bool:
        """Grow the block table to cover this step's write positions
        (through ``pos + decode_lookahead`` when a draft window rides
        ahead of the accepted context)."""
        pos = len(req.context) - 1
        need = self._pages_needed(pos + 1 + self.cfg.decode_lookahead)
        have = len(self.pool.pages_of(req.rid))
        if need <= have:
            return True
        grown = self.pool.allocate(need - have, req.rid,
                                   shard=self._shard(req))
        return grown is not None

    def schedule(self) -> StepPlan:
        plan = StepPlan(prefill=[], decode=[])

        # KV2 precision ladder: only the decode set's pages may be
        # demoted — everyone else (mid-prefill prompts) is read through
        # tier-unaware gathers. Refresh the pool's demotable set before
        # any pressure handling so the ladder rung below can act.
        if self.pool.kv2_armed:
            self.pool.set_demotable(
                [r.rid for r in self.running if r.status == RUNNING])

        # 1. decode set — grow pages, preempting the youngest on pressure.
        # The victim can be OLDER than the request that hit pressure (when
        # that request is itself the youngest), so the decode list is only
        # finalized after every grow/preempt has settled.
        for req in sorted(self.running, key=lambda r: (r.arrival, r.rid)):
            if req.status != RUNNING:
                continue
            while not self._ensure_decode_page(req):
                # eviction ladder, rung 1 (KV4 -> KV2): demote the
                # shard's coldest demotable page to free a KV4 page
                # before anyone is preempted (rung 2: KV2 -> drop)
                if self.pool.demote_for_pressure(self._shard(req)):
                    continue
                # only a victim holding pages in the SAME data shard can
                # relieve this request's pressure (per-shard free lists)
                shard = self._shard(req)
                victims = [r for r in self.running
                           if r is not req and r.status == RUNNING
                           and self._shard(r) == shard]
                # mid-prefill waiters hold pages too — fair game, they
                # haven't produced a token yet
                victims += [r for r in self.waiting
                            if r is not req and self.pool.pages_of(r.rid)
                            and self.pool.shard_of(r.rid) == shard]
                victim = max(victims, key=lambda r: (r.arrival, r.rid),
                             default=None)
                if victim is None:
                    # sole page-holder and out of pages: self-preempt is
                    # pointless — submit() guaranteed a lone request fits
                    raise RuntimeError("page pool exhausted by one request")
                self.preempt(victim)
        plan.decode = [r for r in sorted(self.running,
                                         key=lambda r: (r.arrival, r.rid))
                       if r.status == RUNNING]

        # 2. prefill — FCFS chunks under the remaining token budget (a
        # speculative decode slot burns 2γ+1 compute tokens, not 1)
        budget = (self.cfg.token_budget
                  - len(plan.decode) * self.cfg.decode_tokens_per_slot)
        for req in list(self.waiting):
            if budget <= 0:
                break
            if req.slot is None:
                if not self._free_slots:
                    break                 # no decode slot to admit into
                req.slot = self._free_slots.pop(0)
            target = len(req.context)
            chunk = min(self.cfg.prefill_chunk, target - req.prefilled,
                        budget)
            need = self._pages_needed(req.prefilled + chunk)
            have = len(self.pool.pages_of(req.rid))
            if need > have:
                if self.pool.allocate(need - have, req.rid,
                                      shard=self._shard(req)) is None:
                    break                 # pool pressure: wait for frees
            if req.status != PREFILL:
                self._lifecycle(req, PREFILL, slot=req.slot)
            req.status = PREFILL
            plan.prefill.append((req, req.prefilled, chunk))
            budget -= chunk
            if req.prefilled + chunk < target:
                break                     # head still mid-prompt: stay FCFS

        # 3. gridlock breaker: every request is mid-prefill holding pages
        # and nobody can move — evict the youngest page-holder so the
        # oldest can finish (only reachable under multi-request pressure)
        if plan.empty and self.has_work() and not self.running:
            holders = [r for r in self.waiting
                       if self.pool.pages_of(r.rid)]
            by_shard: dict = {}
            for r in holders:
                by_shard.setdefault(self.pool.shard_of(r.rid), []).append(r)
            # a shard with >1 holders is contended: evict its youngest so
            # the older one can finish (unsharded pools: shard 0 holds
            # everyone, reproducing the original global rule)
            crowded = [rs for rs in by_shard.values() if len(rs) > 1]
            if crowded:
                self.preempt(max(crowded[0],
                                 key=lambda r: (r.arrival, r.rid)))
                return self.schedule()
            raise RuntimeError(
                "scheduler gridlock: pool too small for the waiting work")
        if self.obs is not None:
            self._m_queue.set(len(self.waiting))
            self._m_running.set(len(self.running))
        return plan
