"""Static invariant checking for the SPARQLe serving stack.

Two layers (see docs/static-analysis.md for the full rule catalog):

* **sparqlint** (`astlint.py`) — AST rules over ``src/``: host-side
  effects must not be reachable from traced code, host-only modules must
  not launch device ops, tracer-leak heuristics, metric registration
  discipline.
* **jaxpr contract checker** (`jaxprcheck.py`) — traces the *real*
  engine step functions from ``launch/steps.py`` on tiny configs
  (without executing them) and walks the ClosedJaxpr to verify the
  representation contracts: one int32 psum per row-parallel linear, no
  un-allowlisted collectives, int32 accumulator dtype discipline, full
  MSB-plane elision under ``msb_skip``, and no host callbacks inside
  serving steps.

CLI: ``python -m repro.analysis --check`` (wired into CI's
``invariants`` job). Intentionally-kept violations live in
``allowlist.txt`` next to this file, each with a reason string.
"""
from __future__ import annotations

import hashlib
import json

VERSION = "1.0.0"

# Rule catalog: ID -> one-line contract statement. The ruleset hash is
# derived from this mapping (plus VERSION), so adding/changing a rule
# changes the hash stamped into bench provenance.
RULES = {
    "SPL001": "no host side effects (print/time/obs registry/tracer) in "
              "functions reachable from jitted, shard_map'd or "
              "pallas_call'd code",
    "SPL002": "no jax.numpy/lax device ops in host-only modules "
              "(serving/scheduler.py, serving/kv_pool.py, obs/)",
    "SPL003": "no tracer-leak patterns (.item()/float()/int()/bool() or "
              "Python control flow on traced values) inside step bodies",
    "SPL004": "metric names registered via MetricsRegistry must be "
              "well-formed and cataloged in docs/observability.md",
    "JXP001": "serving step jaxprs contain no collectives outside the "
              "committed allowlist",
    "JXP002": "exactly one int32 psum over the model axis per "
              "row-parallel linear, paired 1:1 with the f32 pmax scale",
    "JXP003": "int32 accumulator untouched by float ops between the int8 "
              "plane matmuls and the rescale convert",
    "JXP004": "msb_skip draft jaxprs contain no MSB-plane matmuls "
              "(int32 dot count halves exactly; no shift-fed dots)",
    "JXP005": "no pure_callback/io_callback/debug_callback/debug.print "
              "inside any serving step jaxpr",
}


def ruleset_hash() -> str:
    """Stable 16-hex digest of the active rule set + analyzer version."""
    blob = json.dumps({"version": VERSION, "rules": RULES}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]
