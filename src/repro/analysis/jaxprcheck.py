"""Jaxpr-level contract checker (layer 2 of the analyzer).

Traces the *real* engine step functions from ``launch/steps.py`` —
prefill / decode / draft (msb_skip) / verify, single-device and
2x2-mesh, transformer and MoE — on tiny configs via ``jax.make_jaxpr``
(nothing executes), then walks the ClosedJaxpr (descending into every
sub-jaxpr carried in eqn params: scan, pjit, cond, shard_map,
pallas_call) and asserts the representation contracts:

* **JXP001** — every collective primitive instance must match the
  committed allowlist (key ``<kind>:<prim>:<axes>:<dtype>``).
* **JXP002** — row-parallel psum discipline: psums over the model axis
  are int32 only (the merged LSB+MSB accumulator — never a float
  partial), paired 1:1 with the f32 pmax that computes the global
  per-token scale, and the transformer step body contains exactly one
  per row-parallel linear (wo + w_down = 2; see docs/sharding.md).
* **JXP003** — int32 accumulator dtype discipline: from each int8-plane
  ``dot_general`` the dataflow stays integer-typed until the single
  ``convert_element_type`` rescale; no float op touches the accumulator.
* **JXP004** — msb_skip elision: the draft jaxpr holds exactly half the
  int32 matmuls of the full step, and none of its matmul operands are
  produced by the MSB-plane extraction (arithmetic right shift).
* **JXP005** — no ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` (jax.debug.print) inside any serving step.

Empirical anchors (jax 0.4.37, tiny 2-layer configs): the full decode
carries 16 int8-plane dots (8 of them shift-fed MSB dots), the draft 8
(0 shift-fed); a 2x2 mesh decode carries exactly 2 int32 ``psum`` and
2 f32 ``pmax`` eqns over the model axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.core import ClosedJaxpr, Jaxpr, JaxprEqn, Literal

from .findings import Finding

COLLECTIVE_PRIMS = {
    "psum", "pmax", "pmin", "all_gather", "all_to_all", "ppermute",
    "pbroadcast", "reduce_scatter", "axis_index",
}
CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback"}

# row-parallel linears per scanned stage body, by family: the attention
# output projection plus the FFN down projection (transformer); MoE adds
# the routed-expert and shared-expert down projections, but its eqn
# count varies per step kind (the verify window unrolls the ffn), so the
# exact-count check is asserted on the transformer decode only.
TRANSFORMER_ROW_SITES = 2

# layout/dtype-preserving ops: following *through* these keeps the
# "produced by a right shift" property of an MSB-plane operand
_LAYOUT_PRIMS = {"convert_element_type", "reshape", "broadcast_in_dim",
                 "squeeze", "transpose"}

# integer-preserving consumers of the int32 accumulator (JXP003)
_INT_OK_PRIMS = {
    "add", "sub", "mul", "neg", "max", "min", "rem", "and", "or", "xor",
    "shift_left", "shift_right_arithmetic", "shift_right_logical",
    "psum", "select_n", "broadcast_in_dim", "reshape", "transpose",
    "squeeze", "slice", "dynamic_slice", "dynamic_update_slice",
    "concatenate", "pad", "gather", "reduce_sum", "reduce_max",
    "expand_dims", "rev", "stop_gradient", "clamp",
}


def iter_eqns(jaxpr: Jaxpr) -> Iterator[Tuple[Jaxpr, int, JaxprEqn]]:
    """Yield (enclosing jaxpr, eqn index, eqn) over every nesting level,
    descending into sub-jaxprs carried in eqn params (scan/pjit/cond/
    while/shard_map/pallas_call kernels)."""
    for i, eqn in enumerate(jaxpr.eqns):
        yield jaxpr, i, eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def _subjaxprs(v) -> Iterator[Jaxpr]:
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _subjaxprs(x)


def _axes_str(eqn: JaxprEqn) -> str:
    ax = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if isinstance(ax, str):
        ax = (ax,)
    return "+".join(str(a) for a in ax) or "-"


def _in_dtype(eqn: JaxprEqn) -> str:
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            return str(aval.dtype)
    return "-"


def _is_int_plane_dot(eqn: JaxprEqn) -> bool:
    return (eqn.primitive.name == "dot_general"
            and str(eqn.outvars[0].aval.dtype) == "int32"
            and all(jnp.issubdtype(v.aval.dtype, jnp.integer)
                    for v in eqn.invars if hasattr(v.aval, "dtype")))


@dataclass
class TracedStep:
    name: str          # e.g. "decode/transformer/mesh"
    kind: str          # prefill | decode | draft | verify
    family: str        # transformer | moe
    mesh: bool
    jaxpr: ClosedJaxpr


# ------------------------------------------------------------- tracing

def tiny_configs() -> Dict[str, object]:
    from repro.configs.base import ModelConfig
    return {
        "transformer": ModelConfig(
            name="lint-tiny", family="transformer", n_layers=2,
            d_model=32, n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
            vocab=128, dtype="float32"),
        "moe": ModelConfig(
            name="lint-tiny-moe", family="moe", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, moe_d_ff=32,
            n_experts=4, top_k=2, n_shared_experts=1, vocab=128,
            dtype="float32"),
    }


def trace_steps(with_mesh: Optional[bool] = None) -> List[TracedStep]:
    """Trace every serving step shape on tiny configs. ``with_mesh``
    None = auto (mesh variants when >= 4 devices are available)."""
    from repro.core.qlinear import quantize_model_params
    from repro.launch import steps as S
    from repro.models.schema import init_params
    from repro.models.schema_builder import build_schema
    from repro.serving.kv_pool import PoolConfig, init_pool_state

    if with_mesh is None:
        with_mesh = len(jax.devices()) >= 4

    B, P, C, T = 2, 4, 8, 3
    pc = PoolConfig(n_pages=8, page_size=4)
    out: List[TracedStep] = []
    for family, cfg in tiny_configs().items():
        fparams = init_params(build_schema(cfg), jax.random.PRNGKey(0))
        qparams = quantize_model_params(fparams, w_bits=4, tile_k=16)
        pool = init_pool_state(cfg, pc)
        meshes: List[Optional[object]] = [None]
        if with_mesh:
            from repro.launch.mesh import make_smoke_mesh
            meshes.append(make_smoke_mesh(data=2, model=2))
        for mesh in meshes:
            tag = "mesh" if mesh is not None else "single"
            kw: Dict[str, object] = {}
            if mesh is not None:
                from repro.distributed import tp
                kw = dict(mesh=mesh,
                          param_specs=tp.param_pspecs(qparams),
                          pool_specs=tp.pool_pspecs(cfg, pc, mesh))

            pre = S.make_engine_prefill_chunk(cfg, **kw)
            out.append(TracedStep(
                f"prefill/{family}/{tag}", "prefill", family,
                mesh is not None,
                jax.make_jaxpr(pre)(
                    qparams, pool, jnp.zeros((1, C), jnp.int32),
                    jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                    jnp.zeros((2, P), jnp.int32))))

            for kind, skip in (("decode", False), ("draft", True)):
                dec = S.make_engine_decode(
                    cfg, msb_skip=skip, with_telemetry=not skip, **kw)
                out.append(TracedStep(
                    f"{kind}/{family}/{tag}", kind, family,
                    mesh is not None,
                    jax.make_jaxpr(dec)(
                        qparams, pool, jnp.zeros((B,), jnp.int32),
                        jnp.zeros((B,), jnp.int32),
                        jnp.zeros((B, P), jnp.int32))))

            ver = S.make_engine_verify_window(cfg, **kw)
            out.append(TracedStep(
                f"verify/{family}/{tag}", "verify", family,
                mesh is not None,
                jax.make_jaxpr(ver)(
                    qparams, pool, jnp.zeros((B, T), jnp.int32),
                    jnp.zeros((B,), jnp.int32),
                    jnp.zeros((B, P), jnp.int32))))
    return out


# --------------------------------------------------------------- rules

def check_collectives(step: TracedStep, out: List[Finding]) -> None:
    """JXP001: every collective must be explicitly allowlisted."""
    for _, i, eqn in iter_eqns(step.jaxpr.jaxpr):
        p = eqn.primitive.name
        if p not in COLLECTIVE_PRIMS:
            continue
        key = f"{step.kind}:{p}:{_axes_str(eqn)}:{_in_dtype(eqn)}"
        out.append(Finding(
            "JXP001", key,
            f"step={step.name} eqn#{i} {p}",
            f"collective `{p}` over axes ({_axes_str(eqn)}) on "
            f"{_in_dtype(eqn)} operands"))


def check_row_psum(step: TracedStep, out: List[Finding]) -> None:
    """JXP002: one int32 psum per row-parallel linear, paired with the
    f32 pmax global-scale reduce."""
    n_psum_model = n_pmax_model = 0
    for _, i, eqn in iter_eqns(step.jaxpr.jaxpr):
        p = eqn.primitive.name
        if p not in ("psum", "pmax"):
            continue
        axes = _axes_str(eqn)
        if "model" not in axes.split("+"):
            continue
        dt = _in_dtype(eqn)
        if p == "psum":
            n_psum_model += 1
            if dt != "int32":
                out.append(Finding(
                    "JXP002", f"{step.kind}:psum:{axes}:{dt}",
                    f"step={step.name} eqn#{i} psum",
                    f"psum over the model axis on {dt} operands — the "
                    "row-parallel reduce must run on the merged int32 "
                    "accumulator, not a float partial"))
        else:
            n_pmax_model += 1
            if dt != "float32":
                out.append(Finding(
                    "JXP002", f"{step.kind}:pmax:{axes}:{dt}",
                    f"step={step.name} eqn#{i} pmax",
                    f"pmax over the model axis on {dt} operands — the "
                    "global per-token scale reduce must be f32"))
    if n_psum_model != n_pmax_model:
        out.append(Finding(
            "JXP002", f"{step.kind}:psum-pmax-pairing",
            f"step={step.name}",
            f"{n_psum_model} int32 psum(s) vs {n_pmax_model} f32 "
            "pmax(es) over the model axis — each row-parallel linear "
            "contributes exactly one of each"))
    if step.mesh and step.family == "transformer" and \
            step.kind == "decode" and \
            n_psum_model != TRANSFORMER_ROW_SITES:
        out.append(Finding(
            "JXP002", f"{step.kind}:row-site-count",
            f"step={step.name}",
            f"expected exactly {TRANSFORMER_ROW_SITES} model-axis psums "
            f"(one per row-parallel linear: wo, w_down), found "
            f"{n_psum_model}"))


def check_acc_dtype(step: TracedStep, out: List[Finding]) -> None:
    """JXP003: int8 planes accumulate in int32, and the accumulator
    stays integer until the rescale."""
    # (a) accumulation width/kind: every dot over int8 operands must
    # produce int32+ — a float output means the planes were accumulated
    # in floating point (rounding breaks bit-exactness), a narrow int
    # output means preferred_element_type was dropped (overflow).
    for _, i, eqn in iter_eqns(step.jaxpr.jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        if not all(str(getattr(v.aval, "dtype", "")) == "int8"
                   for v in eqn.invars):
            continue
        odt = eqn.outvars[0].aval.dtype
        if not jnp.issubdtype(odt, jnp.integer):
            out.append(Finding(
                "JXP003", f"{step.kind}:float-accum",
                f"step={step.name} eqn#{i} dot_general",
                f"int8-plane matmul accumulates in {odt} — the dual-pass "
                "accumulator must be int32 (bit-exactness)"))
        elif jnp.iinfo(odt).bits < 32:
            out.append(Finding(
                "JXP003", f"{step.kind}:narrow-accum",
                f"step={step.name} eqn#{i} dot_general",
                f"int8-plane matmul accumulates in {odt} — narrower than "
                "int32, the accumulator can overflow"))
    # (b) dataflow discipline: from each int32 accumulator, only
    # integer ops until the convert_element_type rescale.
    for jaxpr, _, _ in _unique_jaxprs(step.jaxpr.jaxpr):
        consumers: Dict[object, List[Tuple[int, JaxprEqn]]] = {}
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.invars:
                if not isinstance(v, Literal):
                    consumers.setdefault(v, []).append((i, eqn))
        frontier = [ov for eqn in jaxpr.eqns if _is_int_plane_dot(eqn)
                    for ov in eqn.outvars]
        seen = set()
        while frontier:
            var = frontier.pop()
            if var in seen:
                continue
            seen.add(var)
            for i, eqn in consumers.get(var, ()):
                p = eqn.primitive.name
                if p == "convert_element_type":
                    # the rescale boundary (int32 -> f32) or an integer
                    # widening — only the former ends tracking
                    if jnp.issubdtype(eqn.outvars[0].aval.dtype,
                                      jnp.integer):
                        frontier.extend(eqn.outvars)
                    continue
                out_float = any(
                    jnp.issubdtype(ov.aval.dtype, jnp.floating)
                    for ov in eqn.outvars if hasattr(ov.aval, "dtype"))
                if p in _INT_OK_PRIMS and not out_float:
                    frontier.extend(eqn.outvars)
                elif out_float:
                    out.append(Finding(
                        "JXP003", f"{step.kind}:{p}",
                        f"step={step.name} eqn#{i} {p}",
                        f"float op `{p}` consumes the int32 accumulator "
                        "before the rescale convert_element_type"))
                # higher-order eqns (scan/pjit/...) end tracking here:
                # their inner jaxprs are checked independently by the
                # outer _unique_jaxprs loop


def _unique_jaxprs(jaxpr: Jaxpr):
    yield jaxpr, 0, None
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _unique_jaxprs(sub)


def count_int_plane_dots(jaxpr: Jaxpr) -> Tuple[int, int]:
    """(total int8-plane dots, dots fed by an MSB-plane right shift)."""
    total = shift_fed = 0
    for sub, _, _ in _unique_jaxprs(jaxpr):
        producer = {}
        for eqn in sub.eqns:
            for ov in eqn.outvars:
                producer[ov] = eqn

        def from_shift(var, depth: int = 0) -> bool:
            if isinstance(var, Literal) or var not in producer or \
                    depth > 8:
                return False
            e = producer[var]
            if e.primitive.name == "shift_right_arithmetic":
                return True
            if e.primitive.name in _LAYOUT_PRIMS:
                return any(from_shift(iv, depth + 1) for iv in e.invars
                           if not isinstance(iv, Literal))
            return False

        for eqn in sub.eqns:
            if _is_int_plane_dot(eqn):
                total += 1
                if any(from_shift(iv) for iv in eqn.invars):
                    shift_fed += 1
    return total, shift_fed


def check_msb_skip(full: TracedStep, draft: TracedStep,
                   out: List[Finding]) -> None:
    """JXP004: the draft holds exactly half the int8-plane matmuls and
    none of them consume the MSB plane (shift-fed operands)."""
    f_total, f_shift = count_int_plane_dots(full.jaxpr.jaxpr)
    d_total, d_shift = count_int_plane_dots(draft.jaxpr.jaxpr)
    if f_shift == 0:
        out.append(Finding(
            "JXP004", f"{full.kind}:msb-detector",
            f"step={full.name}",
            "detector self-check failed: the full step shows no "
            "shift-fed MSB-plane matmuls — the MSB extraction signature "
            "changed and the elision check is blind"))
    if d_total * 2 != f_total:
        out.append(Finding(
            "JXP004", f"{draft.kind}:dot-halving",
            f"step={draft.name}",
            f"msb_skip draft has {d_total} int8-plane matmuls vs "
            f"{f_total} in the full step — expected exactly half (the "
            "MSB pass statically elided)"))
    if d_shift != 0:
        out.append(Finding(
            "JXP004", f"{draft.kind}:msb-dot",
            f"step={draft.name}",
            f"{d_shift} matmul(s) in the msb_skip draft consume an "
            "MSB-plane operand (produced by the >>4 extraction) — the "
            "sparse plane leaked into the draft datapath"))


def check_callbacks(step: TracedStep, out: List[Finding]) -> None:
    """JXP005: no host callbacks inside serving steps."""
    for _, i, eqn in iter_eqns(step.jaxpr.jaxpr):
        p = eqn.primitive.name
        if p in CALLBACK_PRIMS or "callback" in p or p == "debug_print":
            out.append(Finding(
                "JXP005", f"{step.kind}:{p}",
                f"step={step.name} eqn#{i} {p}",
                f"host callback `{p}` inside a serving step jaxpr"))


def run(with_mesh: Optional[bool] = None) -> List[Finding]:
    steps = trace_steps(with_mesh=with_mesh)
    out: List[Finding] = []
    for st in steps:
        check_collectives(st, out)
        check_row_psum(st, out)
        check_acc_dtype(st, out)
        check_callbacks(st, out)
    by_name = {st.name: st for st in steps}
    for st in steps:
        if st.kind == "draft":
            full = by_name[st.name.replace("draft/", "decode/")]
            check_msb_skip(full, st, out)
    return out
