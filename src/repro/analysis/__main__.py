"""CLI: ``python -m repro.analysis [--check] [--report out.json]``.

Runs both analysis layers and prints every finding. ``--check`` exits
non-zero when any non-allowlisted finding remains (the CI gate).

The jaxpr layer needs >= 4 devices to trace the 2x2-mesh step variants,
so when XLA_FLAGS doesn't already force a host device count this module
injects ``--xla_force_host_platform_device_count=4`` *before* jax
initializes its backends — which is why the heavy imports below are
deferred until after the environment is set up.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="SPARQLe invariant checker (AST lint + jaxpr "
                    "contract verification)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any non-allowlisted finding remains")
    ap.add_argument("--report", metavar="PATH",
                    help="write a JSON findings report")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr layer (AST rules only; no jax "
                         "import)")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the mesh-sharded step traces")
    ap.add_argument("--devices", type=int, default=4,
                    help="host device count to force for mesh traces "
                         "(default 4; ignored if XLA_FLAGS already "
                         "forces one)")
    args = ap.parse_args(argv)

    if not args.no_jaxpr and not args.no_mesh and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from . import VERSION, ruleset_hash
    from .findings import Allowlist, apply_allowlist

    here = os.path.dirname(os.path.abspath(__file__))
    repo_root = os.path.abspath(os.path.join(here, "..", "..", ".."))
    src_root = os.path.join(repo_root, "src")
    docs = os.path.join(repo_root, "docs", "observability.md")

    from . import astlint
    findings = astlint.run(src_root, docs_path=docs)
    if not args.no_jaxpr:
        from . import jaxprcheck
        findings += jaxprcheck.run(
            with_mesh=False if args.no_mesh else None)

    allowlist = Allowlist.load()
    active, allowed = apply_allowlist(findings, allowlist)

    for f in active:
        print(f.render())
    print(f"repro.analysis v{VERSION} (ruleset {ruleset_hash()}): "
          f"{len(active)} finding(s), {len(allowed)} allowlisted")
    stale = allowlist.stale_entries()
    if args.no_jaxpr:  # JXP entries can't match when the layer is skipped
        stale = [e for e in stale if not e.rule_id.startswith("JXP")]
    for e in stale:
        print(f"warning: stale allowlist entry (matched nothing): "
              f"{allowlist.path}:{e.line_no} {e.rule_id} {e.pattern}")

    if args.report:
        with open(args.report, "w") as f:
            json.dump({
                "version": VERSION,
                "ruleset_hash": ruleset_hash(),
                "findings": [x.as_dict() for x in active],
                "allowlisted": [x.as_dict() for x in allowed],
                "stale_allowlist_entries": [
                    {"rule_id": e.rule_id, "pattern": e.pattern,
                     "reason": e.reason, "line": e.line_no}
                    for e in stale],
            }, f, indent=2)
        print(f"report written to {args.report}")

    return 1 if (args.check and active) else 0


if __name__ == "__main__":
    sys.exit(main())
