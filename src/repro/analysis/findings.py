"""Findings + allowlist plumbing shared by both analysis layers.

A ``Finding`` is one rule violation: rule ID, a *stable* match key (used
for allowlisting — file::symbol for AST rules, step:primitive:axes:dtype
for jaxpr rules), human-readable provenance (file:line or jaxpr eqn
coordinates) and a message.

The committed allowlist (``src/repro/analysis/allowlist.txt``) holds
intentionally-grandfathered findings, one per line::

    RULE_ID  MATCH_KEY  reason the violation is deliberate

``MATCH_KEY`` is matched with ``fnmatch`` so entries may use ``*``
wildcards (e.g. ``decode:psum:model:int32`` appearing in every step kind
is covered by ``*:psum:model:int32``). Every entry must carry a reason
string; entries that match nothing are reported as stale so the file
cannot silently rot.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import Dict, List, Tuple

ALLOWLIST_PATH = os.path.join(os.path.dirname(__file__), "allowlist.txt")


@dataclass
class Finding:
    rule_id: str          # e.g. "SPL001", "JXP002"
    key: str              # stable allowlist match key
    provenance: str       # file:line or "step=<name> eqn#<i> <prim>"
    message: str
    allowlisted: bool = False
    allow_reason: str = ""

    def render(self) -> str:
        tag = " [allowlisted: %s]" % self.allow_reason if self.allowlisted \
            else ""
        return f"{self.rule_id} {self.provenance}: {self.message}{tag}"

    def as_dict(self) -> Dict[str, object]:
        return {
            "rule_id": self.rule_id, "key": self.key,
            "provenance": self.provenance, "message": self.message,
            "allowlisted": self.allowlisted,
            "allow_reason": self.allow_reason,
        }


@dataclass
class AllowEntry:
    rule_id: str
    pattern: str
    reason: str
    line_no: int
    hits: int = 0


@dataclass
class Allowlist:
    entries: List[AllowEntry] = field(default_factory=list)
    path: str = ""

    @classmethod
    def load(cls, path: str = ALLOWLIST_PATH) -> "Allowlist":
        al = cls(path=path)
        if not os.path.exists(path):
            return al
        with open(path) as f:
            for i, raw in enumerate(f, start=1):
                line = raw.strip()
                if not line or line.startswith("#"):
                    continue
                parts = line.split(None, 2)
                if len(parts) < 3:
                    raise ValueError(
                        f"{path}:{i}: allowlist entries need "
                        f"'RULE_ID KEY reason...', got: {line!r}")
                al.entries.append(AllowEntry(parts[0], parts[1], parts[2], i))
        return al

    def match(self, finding: Finding) -> AllowEntry | None:
        for e in self.entries:
            if e.rule_id == finding.rule_id and \
                    fnmatchcase(finding.key, e.pattern):
                return e
        return None

    def stale_entries(self) -> List[AllowEntry]:
        return [e for e in self.entries if e.hits == 0]


def apply_allowlist(findings: List[Finding],
                    allowlist: Allowlist) -> Tuple[List[Finding],
                                                   List[Finding]]:
    """Split findings into (active, allowlisted); marks matches in place."""
    active, allowed = [], []
    for f in findings:
        e = allowlist.match(f)
        if e is not None:
            e.hits += 1
            f.allowlisted, f.allow_reason = True, e.reason
            allowed.append(f)
        else:
            active.append(f)
    return active, allowed
