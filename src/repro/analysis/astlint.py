"""sparqlint — AST rules over ``src/repro`` (layer 1 of the analyzer).

Rules (catalog + rationale in docs/static-analysis.md):

* **SPL001** — functions reachable from traced roots (jit-decorated
  functions, pallas kernels, shard_map bodies, the step factories in
  ``launch/steps.py``) must not perform host side effects: ``print``,
  ``time.*``, or obs registry/tracer calls. Instrumentation brackets the
  jitted calls, it never runs inside them (docs/observability.md).
* **SPL002** — host-only modules (``serving/scheduler.py``,
  ``serving/kv_pool.py``, ``obs/``) must not launch device ops
  (``jnp.*``/``jax.lax.*``/``jax.nn.*`` calls). Scheduler and pool
  bookkeeping stays collective-free host work (docs/sharding.md).
* **SPL003** — tracer-leak heuristics inside traced code: ``.item()``,
  ``float()/int()/bool()`` applied to jnp/jax expressions, and Python
  ``if``/``while`` tests calling into jnp/jax — each forces a trace-time
  concretization error or a silent host sync.
* **SPL004** — metric registration discipline: every literal name passed
  to ``.counter()/.gauge()/.histogram()`` must match the registry's
  naming rule, counters must end in ``_total``, and the name must be
  cataloged in docs/observability.md.

The call graph is intentionally lightweight: same-module calls by name,
cross-module calls through ``import``/``from`` aliases, plus any known
function *referenced* as a call argument (covers ``lax.scan(body, ...)``,
``pallas_call(kernel, ...)``, ``shard_map_compat(body, ...)``).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .findings import Finding

METRIC_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")

HOST_ONLY = ("serving/scheduler.py", "serving/kv_pool.py", "obs/")

# attribute roots that mark an expression as device-side jax
_JAX_DEVICE_SUBMODULES = {"lax", "nn", "numpy"}
# obs-object names whose method calls are host side effects
_OBS_NAMES = {"obs", "registry", "tracer"}
# method names that are registry mutations wherever they appear
_OBS_METHODS = {"inc", "observe"}
_TIME_FNS = {"time", "perf_counter", "perf_counter_ns", "monotonic",
             "monotonic_ns", "sleep", "process_time"}


@dataclass
class FuncInfo:
    module: str            # dotted module name, e.g. "repro.kernels.ops"
    path: str              # repo-relative file path
    qualname: str          # e.g. "make_engine_decode.body"
    node: ast.FunctionDef
    is_root: bool = False
    calls: Set[Tuple[str, str]] = field(default_factory=set)  # (mod, name)


@dataclass
class ModuleInfo:
    name: str
    path: str
    tree: ast.Module
    # alias -> dotted module ("jnp" -> "jax.numpy")
    mod_aliases: Dict[str, str] = field(default_factory=dict)
    # local name -> (source module, symbol) for `from x import y`
    sym_imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    functions: Dict[str, FuncInfo] = field(default_factory=dict)


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """`a.b.c` -> ["a", "b", "c"]; None for non-name-rooted chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


class _Repo:
    """Parsed view of every module under a source root."""

    def __init__(self, src_root: str):
        self.src_root = src_root
        self.modules: Dict[str, ModuleInfo] = {}
        for dirpath, _, names in sorted(os.walk(src_root)):
            if "__pycache__" in dirpath:
                continue
            for fn in sorted(names):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, src_root)
                dotted = rel[:-3].replace(os.sep, ".")
                if dotted.endswith(".__init__"):
                    dotted = dotted[: -len(".__init__")]
                with open(path) as f:
                    tree = ast.parse(f.read(), filename=path)
                self.modules[dotted] = ModuleInfo(dotted, rel, tree)
        for mi in self.modules.values():
            self._index_module(mi)
        for mi in self.modules.values():
            for fi in mi.functions.values():
                self._collect_calls(mi, fi)

    # -- indexing ----------------------------------------------------
    def _index_module(self, mi: ModuleInfo) -> None:
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mi.mod_aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    if full in self.modules or a.name == "*":
                        mi.mod_aliases[a.asname or a.name] = full
                    else:
                        mi.sym_imports[a.asname or a.name] = \
                            (node.module, a.name)

        def visit(node: ast.AST, prefix: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{prefix}.{child.name}" if prefix else child.name
                    mi.functions[q] = FuncInfo(mi.name, mi.path, q, child)
                    visit(child, q)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}.{child.name}"
                          if prefix else child.name)
                else:
                    visit(child, prefix)

        visit(mi.tree, "")
        self._mark_roots(mi)

    def _is_jit_decorator(self, mi: ModuleInfo, dec: ast.AST) -> bool:
        chain = _attr_chain(dec.func if isinstance(dec, ast.Call) else dec)
        if chain is None:
            return False
        if chain[-1] == "jit":
            return True
        # functools.partial(jax.jit, ...)
        if isinstance(dec, ast.Call) and chain[-1] == "partial" and dec.args:
            inner = _attr_chain(dec.args[0])
            return inner is not None and inner[-1] == "jit"
        return False

    def _mark_roots(self, mi: ModuleInfo) -> None:
        # (a) jit-decorated functions anywhere
        for fi in mi.functions.values():
            for dec in fi.node.decorator_list:
                if self._is_jit_decorator(mi, dec):
                    fi.is_root = True
        # (b) nested defs inside make_* factories in launch/steps.py —
        # these are the engine step bodies handed to jax.jit/shard_map
        if mi.name.endswith("launch.steps"):
            for q, fi in mi.functions.items():
                parts = q.split(".")
                if len(parts) > 1 and parts[0].startswith("make_"):
                    fi.is_root = True
        # (c) functions passed by name to pallas_call / shard_map*
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain is None or not node.args:
                continue
            if chain[-1] in ("pallas_call", "shard_map",
                            "shard_map_compat"):
                target = node.args[0]
                if isinstance(target, ast.Call):   # partial(kernel, ...)
                    target = target.args[0] if target.args else target
                tchain = _attr_chain(target)
                if tchain and len(tchain) == 1:
                    for q, fi in mi.functions.items():
                        if q.split(".")[-1] == tchain[0]:
                            fi.is_root = True

    def _resolve(self, mi: ModuleInfo, fi: FuncInfo,
                 name: str) -> Optional[Tuple[str, str]]:
        # innermost enclosing scope first: sibling/nested defs, then
        # module-level defs, then from-imports
        parts = fi.qualname.split(".")
        for depth in range(len(parts), -1, -1):
            q = ".".join(parts[:depth] + [name])
            if q in mi.functions:
                return (mi.name, q)
        if name in mi.sym_imports:
            smod, sym = mi.sym_imports[name]
            if smod in self.modules and sym in self.modules[smod].functions:
                return (smod, sym)
        return None

    def _collect_calls(self, mi: ModuleInfo, fi: FuncInfo) -> None:
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            # direct calls: f(...) / mod.f(...)
            if isinstance(node.func, ast.Name):
                tgt = self._resolve(mi, fi, node.func.id)
                if tgt:
                    fi.calls.add(tgt)
            else:
                chain = _attr_chain(node.func)
                if chain and len(chain) == 2 and \
                        chain[0] in mi.mod_aliases:
                    smod = mi.mod_aliases[chain[0]]
                    if smod in self.modules and \
                            chain[1] in self.modules[smod].functions:
                        fi.calls.add((smod, chain[1]))
            # higher-order: any known function referenced as an argument
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, ast.Name):
                    tgt = self._resolve(mi, fi, arg.id)
                    if tgt:
                        fi.calls.add(tgt)

    def reachable_from_roots(self) -> Set[Tuple[str, str]]:
        seen: Set[Tuple[str, str]] = set()
        frontier = [(mi.name, q) for mi in self.modules.values()
                    for q, fi in mi.functions.items() if fi.is_root]
        seen.update(frontier)
        while frontier:
            mod, q = frontier.pop()
            fi = self.modules[mod].functions[q]
            for tgt in fi.calls:
                if tgt not in seen:
                    seen.add(tgt)
                    frontier.append(tgt)
        return seen


# ---------------------------------------------------------------- rules

def _is_device_attr_call(mi: ModuleInfo,
                         chain: List[str]) -> bool:
    """True for jnp.foo(...) / jax.lax.foo(...) / jax.nn.foo(...)."""
    root = mi.mod_aliases.get(chain[0], chain[0])
    if root == "jax.numpy":
        return True
    if root == "jax" and len(chain) >= 3 and \
            chain[1] in _JAX_DEVICE_SUBMODULES:
        return True
    return False


def _contains_jax_expr(mi: ModuleInfo, node: ast.AST) -> bool:
    for sub in ast.walk(node):
        chain = _attr_chain(sub) if isinstance(sub, ast.Attribute) else None
        if chain:
            root = mi.mod_aliases.get(chain[0], chain[0])
            if root == "jax.numpy" or root == "jax":
                return True
    return False


def _check_spl001(repo: _Repo, reachable: Set[Tuple[str, str]],
                  out: List[Finding]) -> None:
    for mod, q in sorted(reachable):
        mi = repo.modules[mod]
        fi = mi.functions[q]
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            msg = None
            if isinstance(node.func, ast.Name):
                if node.func.id == "print":
                    msg = "print() call in traced code"
                elif node.func.id in _TIME_FNS and \
                        node.func.id in mi.sym_imports and \
                        mi.sym_imports[node.func.id][0] == "time":
                    msg = f"time.{node.func.id}() call in traced code"
            else:
                chain = _attr_chain(node.func)
                if chain:
                    root = mi.mod_aliases.get(chain[0], chain[0])
                    if root == "time" and chain[-1] in _TIME_FNS:
                        msg = f"time.{chain[-1]}() call in traced code"
                    elif any(p in _OBS_NAMES for p in chain[:-1]):
                        msg = (f"obs call {'.'.join(chain)}() in traced "
                               "code (instrumentation must stay host-side)")
                    elif chain[-1] in _OBS_METHODS:
                        msg = (f"metric mutation .{chain[-1]}() in traced "
                               "code")
            if msg:
                out.append(Finding(
                    "SPL001", f"{fi.path}::{q}",
                    f"{fi.path}:{node.lineno}", f"{msg} (in `{q}`)"))


def _check_spl002(repo: _Repo, out: List[Finding]) -> None:
    for mi in repo.modules.values():
        if not any(mi.path.startswith(p) or f"/{p}" in f"/{mi.path}"
                   for p in HOST_ONLY):
            continue

        def enclosing(lineno: int) -> str:
            best = ""
            for q, fi in mi.functions.items():
                n = fi.node
                if n.lineno <= lineno <= (n.end_lineno or n.lineno) and \
                        len(q) > len(best):
                    best = q
            return best or "<module>"

        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain and _is_device_attr_call(mi, chain):
                fn = enclosing(node.lineno)
                out.append(Finding(
                    "SPL002", f"{mi.path}::{fn}",
                    f"{mi.path}:{node.lineno}",
                    f"device op {'.'.join(chain)}() in host-only module "
                    f"(in `{fn}`)"))


def _check_spl003(repo: _Repo, reachable: Set[Tuple[str, str]],
                  out: List[Finding]) -> None:
    for mod, q in sorted(reachable):
        mi = repo.modules[mod]
        fi = mi.functions[q]
        for node in ast.walk(fi.node):
            msg = None
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    msg = ".item() concretizes a traced value"
                elif isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "int", "bool") and \
                        node.args and \
                        _contains_jax_expr(mi, node.args[0]):
                    msg = (f"{node.func.id}() on a jnp/jax expression "
                           "concretizes a traced value")
            elif isinstance(node, (ast.If, ast.While)):
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Call):
                        chain = _attr_chain(sub.func)
                        if chain and _is_device_attr_call(mi, chain):
                            msg = ("Python control flow on a traced "
                                   f"value ({'.'.join(chain)}(...))")
                            break
            if msg:
                out.append(Finding(
                    "SPL003", f"{fi.path}::{q}",
                    f"{fi.path}:{node.lineno}", f"{msg} (in `{q}`)"))


def _check_spl004(repo: _Repo, docs_path: str,
                  out: List[Finding]) -> None:
    docs = ""
    if os.path.exists(docs_path):
        with open(docs_path) as f:
            docs = f.read()
    for mi in repo.modules.values():
        if mi.name == "repro.obs.metrics" or mi.name.endswith(".obs"):
            # the registry implementation itself (its internal helper
            # calls are not registrations); other obs/ modules
            # (attribution, slo, ...) register real metrics and must
            # catalog them like everyone else
            continue
        for node in ast.walk(mi.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("counter", "gauge", "histogram")
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            name = node.args[0].value
            prov = f"{mi.path}:{node.lineno}"
            key = f"{mi.path}::{name}"
            if not METRIC_NAME_RE.match(name):
                out.append(Finding(
                    "SPL004", key, prov,
                    f"metric name `{name}` violates ^[a-z][a-z0-9_]*$"))
            if node.func.attr == "counter" and \
                    not name.endswith("_total"):
                out.append(Finding(
                    "SPL004", key, prov,
                    f"counter `{name}` should end in `_total`"))
            if docs and f"`{name}`" not in docs:
                out.append(Finding(
                    "SPL004", key, prov,
                    f"metric `{name}` is not cataloged in "
                    "docs/observability.md"))


def run(src_root: str, docs_path: str = "") -> List[Finding]:
    """Run all AST rules over ``src_root`` (a directory containing the
    ``repro`` package or any module tree). Returns raw findings —
    allowlist application happens in the caller."""
    repo = _Repo(src_root)
    reachable = repo.reachable_from_roots()
    out: List[Finding] = []
    _check_spl001(repo, reachable, out)
    _check_spl002(repo, out)
    _check_spl003(repo, reachable, out)
    _check_spl004(repo, docs_path, out)
    return out
