"""SPARQLe quantized linear — the paper's technique as a drop-in layer.

``SparqleLinear`` bundles everything a deployed SPARQLe linear needs:
the int4/int2 quantized weight, the precomputed column-importance mask
(paper §3.2 — offline, zero runtime overhead) and the calibrated clipping
constants.  ``linear()`` is the single projection entry point used by every
model family: it dispatches transparently between

  * plain float weights                  (training / float serving),
  * ``SparqleLinear`` in ``sparqle`` mode (dual-pass sub-precision execution),
  * ``SparqleLinear`` in ``dense`` mode   (the paper's W4A8 dense baseline).

``quantize_model_params`` converts a float param tree into its served form
by rewriting projection leaves in place — models need no code changes to
run quantized (the "complementary to quantization" contribution of §1).
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.clipping import apply_clipping, importance_mask_tile_aligned
from repro.core.packing import encode_packed, unpack_planes
from repro.core.quantize import (QuantizedTensor, quantize_activations,
                                 quantize_weights)
from repro.core.sparqle import encode
from repro.distributed.tp import tp_ctx


# Trace-time draft-mode flag (self-speculative decoding): while True, every
# sparqle-mode projection runs LSB4-only — the sparse MSB pass is elided
# from the traced program entirely, so a jitted function traced under
# msb_skip_scope() IS the 1-compute-round draft forward (paper §3.3: the
# full hybrid pass costs 1 + (1 - s) rounds). Read at trace time only; it
# must wrap the whole trace (e.g. the body of a jitted step function),
# not individual calls of an already-compiled one.
_MSB_SKIP = False


@contextlib.contextmanager
def msb_skip_scope(enabled: bool = True):
    """Trace every sparqle projection in LSB4-only (draft) mode."""
    global _MSB_SKIP
    prev = _MSB_SKIP
    _MSB_SKIP = enabled
    try:
        yield
    finally:
        _MSB_SKIP = prev


def msb_skip_active() -> bool:
    return _MSB_SKIP


def pack_int4(q: jax.Array, axis: int = -2) -> jax.Array:
    """Pack two's-complement int4 values two-per-byte along ``axis``.

    The sub-byte wire format the paper's representation implies, applied
    to the static weights: halves the weight HBM stream (the dominant
    decode bytes after the KV cache).
    """
    assert q.shape[axis] % 2 == 0, q.shape
    lo = jnp.take(q, jnp.arange(0, q.shape[axis], 2), axis=axis)
    hi = jnp.take(q, jnp.arange(1, q.shape[axis], 2), axis=axis)
    return jnp.bitwise_or(
        jnp.bitwise_and(lo, 0xF),
        jnp.left_shift(jnp.bitwise_and(hi, 0xF), 4)).astype(jnp.int8)


def unpack_int4(q: jax.Array, axis: int = -2) -> jax.Array:
    """Inverse of :func:`pack_int4` (sign-extending)."""
    lo = jnp.right_shift(jnp.left_shift(q, 4), 4)
    hi = jnp.right_shift(q, 4)
    stacked = jnp.stack([lo, hi], axis=axis + 1 if axis >= 0
                        else q.ndim + axis + 1)
    shape = list(q.shape)
    shape[axis] = shape[axis] * 2
    return stacked.reshape(shape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparqleLinear:
    """A quantized projection in SPARQLe served form.

    ``w.q`` is (K, N) or batched (E, K, N) for expert weights — stored
    nibble-PACKED along K ((K/2, N)) when ``packed``. ``col_mask`` marks
    the k% least-important activation columns (per expert for batched
    weights); ``l``/``h`` are the calibrated clipping constants.
    Aux (untraced): ``mode`` ('sparqle' | 'dense'), ``packed``, and
    ``wire_format`` ('unpacked' | 'packed') — the latter routes the
    *activation* stream through the packed sub-precision wire format
    (``core/packing.py``) before the dual-pass matmul.
    """

    w: QuantizedTensor
    col_mask: Optional[jax.Array]   # (K,) or (E, K) bool; None = no clipping
    l: Optional[jax.Array]          # scalar f32 (integer-domain)
    h: Optional[jax.Array]
    mode: str = "sparqle"
    packed: bool = False
    wire_format: str = "unpacked"

    def tree_flatten(self):
        return (self.w, self.col_mask, self.l, self.h), (
            self.mode, self.packed, self.wire_format)

    @classmethod
    def tree_unflatten(cls, aux, children):
        aux = aux if isinstance(aux, tuple) else (aux,)
        mode = aux[0]
        packed = aux[1] if len(aux) > 1 else False
        wf = aux[2] if len(aux) > 2 else "unpacked"
        return cls(*children, mode=mode, packed=packed, wire_format=wf)

    def unpacked_q(self) -> jax.Array:
        q = self.w.q.astype(jnp.int8)
        return unpack_int4(q) if self.packed else q

    def dequantize(self) -> jax.Array:
        return self.unpacked_q().astype(jnp.float32) * self.w.scale \
            + self.w.zero

    @property
    def shape(self):
        s = list(self.w.q.shape)
        if self.packed:
            s[-2] *= 2
        return tuple(s)


def _dual_pass_matmul(q: jax.Array, wq: jax.Array, batched: bool,
                      wire_format: str = "unpacked",
                      msb_skip: bool = False) -> jax.Array:
    """int8 SPARQLe activations x int-weights -> int32, dual nibble passes.

    ``wire_format='packed'`` round-trips the activations through the packed
    sub-precision wire format first, making the wire layout — not the dense
    int8 tensor — the source of truth the matmul consumes. The codec is an
    exact inverse pair, so both formats produce bit-identical accumulators.

    ``msb_skip`` drops the sparse pass from the traced program: the result
    is the dense LSB4 contribution alone (equal to dequantizing the LSB
    plane by itself), the draft forward of self-speculative decoding.
    """
    if wire_format == "packed":
        pa = encode_packed(q.reshape(-1, q.shape[-1]))
        planes = unpack_planes(pa)
        lsb = planes.lsb4.reshape(q.shape)
        msb = planes.msb4.reshape(q.shape)
    else:
        act = encode(q)
        lsb, msb = act.lsb4, act.msb4
    if batched:   # (E, C, K) x (E, K, N)
        dims = (((2,), (1,)), ((0,), (0,)))
    else:         # (M, K) x (K, N)
        dims = (((1,), (0,)), ((), ()))
    dense = jax.lax.dot_general(lsb, wq, dims,
                                preferred_element_type=jnp.int32)
    if msb_skip:
        return dense
    sparse = jax.lax.dot_general(msb, wq, dims,
                                 preferred_element_type=jnp.int32)
    return dense + sparse * 16


def _single_pass_matmul(q: jax.Array, wq: jax.Array, batched: bool) -> jax.Array:
    dims = (((2,), (1,)), ((0,), (0,))) if batched else (((1,), (0,)), ((), ()))
    return jax.lax.dot_general(q, wq, dims, preferred_element_type=jnp.int32)


def linear(x: jax.Array, w, b: Optional[jax.Array] = None, *,
           tp: Optional[str] = None) -> jax.Array:
    """Universal projection: x (..., K) @ w (K, N) [+ b].

    ``w`` may be a float array, a :class:`SparqleLinear`, or (batched expert
    form) x (E, C, K) @ w (E, K, N).

    ``tp="row"`` marks this call site as row-parallel under tensor
    parallelism (``distributed/tp.py``): when a TP trace is active the
    input features and weight K dim are sharded over the model axis, the
    per-token activation scale is taken over the GLOBAL row (exact pmax)
    and the int32 accumulator is reduced with ONE psum before rescaling
    (bias added after, on the replicated output). Inert otherwise —
    single-device traces are unchanged.
    """
    if isinstance(w, SparqleLinear):
        y = _quantized_apply(x, w, tp=tp)
    else:
        y = jax.lax.dot_general(
            x, w.astype(x.dtype),
            (((x.ndim - 1,), (0,)), ((), ())))
        ctx = tp_ctx()
        if tp == "row" and ctx is not None and ctx.ways > 1:
            y = jax.lax.psum(y, ctx.axis)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def expert_linear(x: jax.Array, w, *, tp: Optional[str] = None) -> jax.Array:
    """Batched expert projection: x (E, C, K) @ w (E, K, N).

    ``tp="row"`` as in :func:`linear` (per-expert K dims sharded; one
    int32 psum of the merged accumulator).
    """
    if isinstance(w, SparqleLinear):
        return _quantized_apply(x, w, batched=True, tp=tp)
    y = jnp.einsum("eck,ekn->ecn", x, w.astype(x.dtype))
    ctx = tp_ctx()
    if tp == "row" and ctx is not None and ctx.ways > 1:
        y = jax.lax.psum(y, ctx.axis)
    return y


def _quantized_apply(x: jax.Array, sl: SparqleLinear,
                     batched: bool = False,
                     tp: Optional[str] = None) -> jax.Array:
    """quantize -> clip -> decompose -> dual-pass -> [psum] -> rescale."""
    ctx = tp_ctx()
    row = tp == "row" and ctx is not None and ctx.ways > 1
    orig = x.shape
    k_in = orig[-1]
    if batched:
        x2 = x                                 # (E, C, K)
    else:
        x2 = x.reshape(-1, k_in)               # (M, K)
    if row:
        # global per-token scale: pmax of local row maxima is exact, so
        # each shard's int8 plane is a slice of the unsharded plane
        amax = jax.lax.pmax(
            jnp.max(jnp.abs(x2), axis=-1, keepdims=True), ctx.axis)
        qa = quantize_activations(x2, bits=8, per_token=True, amax=amax)
    else:
        qa = quantize_activations(x2, bits=8, per_token=True)
    q = qa.q
    if sl.col_mask is not None and sl.l is not None:
        mask = sl.col_mask[:, None, :] if batched else sl.col_mask
        q = apply_clipping(q, mask, sl.l, sl.h)
    wq = sl.unpacked_q()
    if sl.mode == "sparqle":
        acc = _dual_pass_matmul(q, wq, batched, sl.wire_format,
                                msb_skip=_MSB_SKIP)
    else:
        acc = _single_pass_matmul(q, wq, batched)
    if row:
        # ONE reduction per linear: the dual-pass accumulator already
        # merged LSB and shifted-MSB partials, and int32 addition is
        # associative — the psum'd accumulator is bit-identical to the
        # single-device one
        acc = jax.lax.psum(acc, ctx.axis)
    w_scale = sl.w.scale  # (1, N) or (E, 1, N) per-output-channel
    out = acc.astype(jnp.float32) * qa.scale.astype(jnp.float32) \
        * w_scale.reshape((wq.shape[0], 1, -1) if batched else (1, -1))
    if batched:
        return out.astype(x.dtype)
    return out.reshape(*orig[:-1], wq.shape[-1]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Offline conversion: float param tree -> SPARQLe served tree
# ---------------------------------------------------------------------------

# param-leaf name patterns eligible for quantization (projection weights);
# norms / embeddings / biases / ssm scalars stay float.
_QUANT_LEAF = re.compile(
    r"(wq|wk|wv|wo|w_gate|w_up|w_down|w_fc|w_proj|w_in|w_out|"
    r"wq_a|wq_b|wkv_a|wkv_b|lm_head|w_shared_gate|w_shared_up|w_shared_down)$")


def is_quantizable(path: str, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    name = path.rsplit("/", 1)[-1]
    return bool(_QUANT_LEAF.search(name))


def quantize_leaf(
    leaf: jax.Array,
    *,
    w_bits: int = 4,
    k_percent: float = 50.0,
    clip_l: float = -8.0,
    clip_h: float = 23.0,
    mode: str = "sparqle",
    tile_k: int = 128,
    enable_clipping: bool = True,
    pack: bool = True,
    wire_format: str = "unpacked",
) -> SparqleLinear:
    """Quantize one (K, N) or (E, K, N) projection into served form.

    ``pack`` nibble-packs the int4 payload two-per-byte along K (halving
    the stored/streamed weight bytes); disabled automatically for odd K
    or w_bits > 4. ``wire_format='packed'`` additionally routes the
    layer's *activations* through the packed sub-precision wire format.
    """
    if leaf.ndim == 2:
        wq = quantize_weights(leaf, bits=w_bits, axis=0)
        mask = (importance_mask_tile_aligned(leaf, k_percent, tile_k)
                if enable_clipping else None)
    elif leaf.ndim == 3:
        wq = quantize_weights(leaf, bits=w_bits, axis=1)
        if enable_clipping:
            mask = jnp.stack([
                importance_mask_tile_aligned(leaf[e], k_percent, tile_k)
                for e in range(leaf.shape[0])])
        else:
            mask = None
    else:
        raise ValueError(f"unsupported weight rank {leaf.ndim}")
    do_pack = pack and w_bits <= 4 and wq.q.shape[-2] % 2 == 0
    if do_pack:
        wq = QuantizedTensor(q=pack_int4(wq.q), scale=wq.scale,
                             zero=wq.zero, bits=wq.bits)
    return SparqleLinear(
        w=wq,
        col_mask=mask,
        l=jnp.float32(clip_l) if enable_clipping else None,
        h=jnp.float32(clip_h) if enable_clipping else None,
        mode=mode,
        packed=do_pack,
        wire_format=wire_format,
    )


def quantize_model_params(
    params: Dict[str, Any],
    *,
    w_bits: int = 4,
    k_percent: float = 50.0,
    clip_l: float = -8.0,
    clip_h: float = 23.0,
    mode: str = "sparqle",
    enable_clipping: bool = True,
    per_layer_lh: Optional[Dict[str, tuple]] = None,
    tile_k: int = 128,
    wire_format: str = "unpacked",
) -> Dict[str, Any]:
    """Rewrite every projection leaf of a param tree into SPARQLe form.

    ``per_layer_lh`` optionally maps path prefixes to (l, h) pairs (the
    Algorithm-1 layerwise constants); unmatched paths use the global pair.
    ``wire_format='packed'`` serves every projection's activations through
    the packed sub-precision wire format.
    """

    def walk(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            path = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                out[k] = walk(v, path)
            elif is_quantizable(path, v):
                l, h = clip_l, clip_h
                if per_layer_lh:
                    for pref, (pl_, ph_) in per_layer_lh.items():
                        if path.startswith(pref):
                            l, h = pl_, ph_
                            break
                q1 = lambda w: quantize_leaf(  # noqa: E731
                    w, w_bits=w_bits, k_percent=k_percent, clip_l=l,
                    clip_h=h, mode=mode, enable_clipping=enable_clipping,
                    tile_k=tile_k, wire_format=wire_format)
                # routed-expert weights are (E,K,N)-batched; shared-expert
                # weights (w_shared_*) are plain 2D despite living in moe/
                is_expert = (("/moe/" in path or path.startswith("moe/"))
                             and "shared" not in k)
                # leaf ranks: 2 = plain (K,N); 3 = experts (E,K,N) when under
                # a moe/ subtree else layer-stacked (L,K,N); 4 = layer-stacked
                # experts (L,E,K,N).
                if v.ndim == 2 or (v.ndim == 3 and is_expert):
                    out[k] = q1(v)
                elif v.ndim in (3, 4):
                    sls = [q1(v[i]) for i in range(v.shape[0])]
                    out[k] = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs), *sls)
                else:
                    raise ValueError(f"{path}: rank {v.ndim}")
            else:
                out[k] = v
        return out

    return walk(params)
