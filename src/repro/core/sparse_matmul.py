"""XLA-level SPARQLe dual-pass matmul (distribution-friendly reference path).

This is the pure-JAX realization of the kernel's math — used (a) as the
lowering path inside pjit'd serving graphs (Pallas interpret mode is
CPU-debug only), and (b) as the numerical contract the Pallas kernel is
tested against. It performs the same two passes the accelerator does:

    acc  = lsb4 @ w                      (dense pass)
    acc += 16 * (msb4 @ w)               (sparse pass, shift-accumulated)

and rescales with the activation/weight quantization scales.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedTensor, quantize_activations
from repro.core.sparqle import SparqleActivation, encode


def sparqle_matmul_xla(
    act: SparqleActivation,
    w: QuantizedTensor,
    *,
    out_dtype=jnp.float32,
    preferred_acc=jnp.int32,
) -> jax.Array:
    """(M, K) SPARQLe activations @ (K, N) quantized weights -> (M, N) real."""
    lsb = act.lsb4.astype(jnp.int8)
    msb = act.msb4.astype(jnp.int8)
    wq = w.q.astype(jnp.int8)
    dense = jax.lax.dot_general(
        lsb, wq, (((lsb.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred_acc)
    sparse = jax.lax.dot_general(
        msb, wq, (((msb.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred_acc)
    acc = dense + sparse * 16
    out = acc.astype(jnp.float32) * act.scale * w.scale.reshape(1, -1)
    if w.zero is not None:
        # symmetric weights in this repo: zero == 0; kept for generality
        out = out + (lsb.astype(jnp.float32) + 16 * msb.astype(jnp.float32)).sum(
            axis=-1, keepdims=True) * 0.0
    return out.astype(out_dtype)


def quantized_linear_sparqle(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    col_mask: Optional[jax.Array] = None,
    clip_l: Optional[jax.Array] = None,
    clip_h: Optional[jax.Array] = None,
    zero_point: bool = False,
) -> jax.Array:
    """Full serving-path linear: quantize -> clip -> decompose -> dual-pass.

    This is what a `QuantizedLinear` layer calls when SPARQLe is enabled.
    Clipping (if configured) is the paper's §3.2 sparsity enhancement,
    applied in the integer domain before decomposition.
    """
    from repro.core.clipping import apply_clipping  # local import, no cycle

    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    qa = quantize_activations(x2, bits=8, per_token=True, zero_point=zero_point)
    q = qa.q
    if col_mask is not None and clip_l is not None:
        q = apply_clipping(q, col_mask, clip_l, clip_h)
    act = encode(q, qa.scale)
    out = sparqle_matmul_xla(act, w)
    if zero_point:
        # x = q*scale + zero  =>  x@W = (q*scale)@W + zero * colsum(W)
        w_colsum = (w.q.astype(jnp.float32) * w.scale).sum(axis=0)
        out = out + qa.zero.reshape(-1, 1) * w_colsum.reshape(1, -1)
    return out.reshape(*orig_shape[:-1], w.q.shape[-1])
