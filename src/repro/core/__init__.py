"""SPARQLe core: codec, quantization, clipping, cost model, reference matmul."""
from repro.core.sparqle import (  # noqa: F401
    SparqleActivation, encode, decode, subprecision_sparsity,
    compression_percent, ops_reduction_percent, tile_population, tile_sparsity,
    LP_LOW, LP_HIGH,
)
from repro.core.quantize import (  # noqa: F401
    QuantizedTensor, quantize_weights, quantize_activations, quantize_kv,
    fake_quantize,
)
from repro.core.clipping import (  # noqa: F401
    column_importance, importance_mask, importance_mask_tile_aligned,
    apply_clipping, soft_clipping, global_calibrate, learn_clipping_constants,
    init_clip_params, enhanced_sparsity,
)
from repro.core.sparse_matmul import (  # noqa: F401
    sparqle_matmul_xla, quantized_linear_sparqle,
)
from repro.core.packing import (  # noqa: F401
    PackedSparqleActivation, encode_packed, decode_packed, unpack_planes,
    planes_packed, pack_nibbles, unpack_nibbles, pack_pbm, unpack_pbm,
    measured_wire_bytes_rows,
)
