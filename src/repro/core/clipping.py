"""Sub-precision sparsity enhancement (paper §3.2, Algorithm 1).

Clipping pushes int8 activation values into the MSB4==0 range [0, 15]:
values in [l, 0) clip to 0, values in (15, h] clip to 15 — but only inside
the ``k``-percent *least important* activation columns, where the importance
of activation column j is the L1 norm of weight row j (errors in column j of
A are scaled by row j of W in A·W).

Two calibration modes:
  * ``global_calibrate`` — sweep one (l, h) pair for the whole model on a
    calibration set, pick the best error/sparsity tradeoff (paper: Llama2/3).
  * ``learn_clipping_constants`` — Algorithm 1: per-layer trainable (l, h),
    all weights frozen, loss = MSE(clip, base) - alpha * mean(clip mask)
    (Eq. 3), optimized with a sigmoid-relaxed soft clip (paper: BitNet).

All clipping operates in the integer (post-quantization-scale) domain, where
the MSB4==0 range is exactly [LP_LOW, LP_HIGH] = [0, 15].
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparqle import LP_HIGH, LP_LOW, subprecision_sparsity


# ---------------------------------------------------------------------------
# Column importance (precomputed offline from weights; zero runtime overhead)
# ---------------------------------------------------------------------------

def column_importance(w: jax.Array) -> jax.Array:
    """L1 norm of each weight row. ``w`` is (K, N) for an A(M,K) @ W(K,N)."""
    return jnp.sum(jnp.abs(w), axis=-1)


def importance_mask(w: jax.Array, k_percent: float) -> jax.Array:
    """Boolean (K,) mask, True on the k% least-important activation columns.

    The paper stores this as a binary mask computed offline; we do the same
    (it becomes a constant folded into the serving graph).
    """
    imp = column_importance(w)
    kk = int(imp.shape[0] * k_percent / 100.0 + 0.5)
    if kk <= 0:
        return jnp.zeros(imp.shape, bool)
    # threshold at the kk-th smallest importance
    thresh = jnp.sort(imp)[kk - 1]
    return imp <= thresh


def importance_mask_tile_aligned(
    w: jax.Array, k_percent: float, tile_k: int
) -> jax.Array:
    """Tile-aligned variant (DESIGN.md §2 co-design note).

    Selects whole ``tile_k``-wide column *blocks* by block-summed importance so
    that clipped columns align with the Pallas kernel's K-tiling — this is
    what converts element-level sub-precision sparsity into skippable MSB4
    tiles on a dense systolic array.
    """
    imp = column_importance(w)
    k = imp.shape[0]
    pad = (-k) % tile_k
    imp_p = jnp.pad(imp, (0, pad), constant_values=jnp.inf)
    blocks = imp_p.reshape(-1, tile_k).sum(axis=1)
    n_blocks = blocks.shape[0]
    kk = int(n_blocks * k_percent / 100.0 + 0.5)
    if kk <= 0:
        return jnp.zeros((k,), bool)
    thresh = jnp.sort(blocks)[kk - 1]
    block_mask = blocks <= thresh
    full = jnp.repeat(block_mask, tile_k)[:k]
    return full


# ---------------------------------------------------------------------------
# Clipping application (deployment: hard; calibration: sigmoid-relaxed)
# ---------------------------------------------------------------------------

def apply_clipping(
    x_int: jax.Array, col_mask: jax.Array, l: jax.Array | float, h: jax.Array | float
) -> jax.Array:
    """Hard clipping in the integer domain (deployment path).

    ``x_int`` is (..., K) integer-domain activations; ``col_mask`` is (K,).
    [l, 0) -> LP_LOW, (15, h] -> LP_HIGH; values outside [l, h] untouched.
    """
    x = x_int.astype(jnp.int32)
    clip_lo = col_mask & (x >= jnp.asarray(l, jnp.int32)) & (x < LP_LOW)
    clip_hi = col_mask & (x > LP_HIGH) & (x <= jnp.asarray(h, jnp.int32))
    y = jnp.where(clip_lo, LP_LOW, jnp.where(clip_hi, LP_HIGH, x))
    return y.astype(x_int.dtype)


def clip_fraction(
    x_int: jax.Array, col_mask: jax.Array, l: jax.Array | float, h: jax.Array | float
) -> jax.Array:
    """Fraction of elements the (l, h) clip actually moves (the mask of Eq. 3)."""
    x = x_int.astype(jnp.int32)
    clip_lo = col_mask & (x >= jnp.asarray(l, jnp.int32)) & (x < LP_LOW)
    clip_hi = col_mask & (x > LP_HIGH) & (x <= jnp.asarray(h, jnp.int32))
    return jnp.mean((clip_lo | clip_hi).astype(jnp.float32))


def soft_clipping(
    x_int: jax.Array,
    col_mask: jax.Array,
    l: jax.Array,
    h: jax.Array,
    tau: float = 1.0,
) -> Tuple[jax.Array, jax.Array]:
    """Differentiable relaxation used by Algorithm 1 training.

    Returns (clipped activations, soft clip mask). The sigmoid gates pass
    gradients to l and h; at tau -> 0 this converges to ``apply_clipping``.
    """
    x = x_int.astype(jnp.float32)
    in_lo_region = (x < LP_LOW).astype(jnp.float32)
    in_hi_region = (x > LP_HIGH).astype(jnp.float32)
    m_lo = jax.nn.sigmoid((x - l) / tau) * in_lo_region * col_mask
    m_hi = jax.nn.sigmoid((h - x) / tau) * in_hi_region * col_mask
    y = x * (1.0 - m_lo - m_hi) + m_lo * LP_LOW + m_hi * LP_HIGH
    return y, m_lo + m_hi


# ---------------------------------------------------------------------------
# Global calibration (sweep)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    l: int
    h: int
    error: float       # calibration MSE between clipped and base outputs
    sparsity: float    # resulting mean sub-precision sparsity
    score: float       # sparsity - lam * normalized error


def global_calibrate(
    eval_fn: Callable[[int, int], Tuple[jax.Array, jax.Array]],
    l_candidates=(-4, -8, -12, -16, -24, -32),
    h_candidates=(19, 23, 31, 39, 47, 63),
    lam: float = 10.0,
) -> SweepResult:
    """Sweep (l, h); ``eval_fn(l, h) -> (mse, sparsity)`` on calibration data.

    Picks the candidate maximizing ``sparsity - lam * mse_norm`` (the paper's
    "best calibration error / sub-precision sparsity tradeoff").
    """
    results = []
    for l in l_candidates:
        for h in h_candidates:
            mse, sp = eval_fn(int(l), int(h))
            results.append((int(l), int(h), float(mse), float(sp)))
    errs = jnp.asarray([r[2] for r in results])
    norm = jnp.maximum(jnp.max(errs), 1e-12)
    best = None
    for (l, h, mse, sp) in results:
        score = sp - lam * mse / float(norm)
        if best is None or score > best.score:
            best = SweepResult(l=l, h=h, error=mse, sparsity=sp, score=float(score))
    return best


# ---------------------------------------------------------------------------
# Algorithm 1: layerwise learned clipping constants
# ---------------------------------------------------------------------------

ClipParams = Dict[str, jax.Array]  # {"l": (n_layers,), "h": (n_layers,)}


def init_clip_params(n_layers: int, l0: float = -8.0, h0: float = 23.0) -> ClipParams:
    return {
        "l": jnp.full((n_layers,), l0, jnp.float32),
        "h": jnp.full((n_layers,), h0, jnp.float32),
    }


def learn_clipping_constants(
    apply_clip: Callable[[ClipParams, jax.Array], Tuple[jax.Array, jax.Array]],
    apply_base: Callable[[jax.Array], jax.Array],
    dataset: jax.Array,
    clip_params: ClipParams,
    *,
    epochs: int = 23,
    lr: float = 0.5,
    alpha: float = 0.05,
) -> Tuple[ClipParams, list]:
    """Algorithm 1 (paper §3.2).

    ``apply_clip(params, batch) -> (outputs, mean_clip_mask)`` runs the model
    with sigmoid-relaxed clipping; ``apply_base(batch)`` runs the frozen base
    model. Only ``clip_params`` receive gradients; loss is Eq. 3:
    ``MSE(clip, base) - alpha * mean(mask)``. Plain SGD, matching the paper's
    "lightweight adaptation" framing. Returns (learned params, loss history).
    """

    def loss_fn(cp, batch, y_base):
        y, mask_mean = apply_clip(cp, batch)
        mse = jnp.mean((y - y_base) ** 2)
        return mse - alpha * mask_mean, (mse, mask_mean)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    history = []
    for _ in range(epochs):
        for batch in dataset:
            y_base = apply_base(batch)
            (loss, (mse, mask_mean)), g = grad_fn(clip_params, batch, y_base)
            clip_params = jax.tree_util.tree_map(
                lambda p, gg: p - lr * gg, clip_params, g
            )
            # keep bounds on the correct side of the MSB4==0 range
            clip_params = {
                "l": jnp.minimum(clip_params["l"], float(LP_LOW)),
                "h": jnp.maximum(clip_params["h"], float(LP_HIGH)),
            }
            history.append(
                {"loss": float(loss), "mse": float(mse), "mask": float(mask_mean)}
            )
    return clip_params, history


def enhanced_sparsity(
    x_int8: jax.Array, col_mask: jax.Array, l: int, h: int
) -> Tuple[jax.Array, jax.Array]:
    """(natural sparsity, post-clipping sparsity) for an activation tensor."""
    nat = subprecision_sparsity(x_int8)
    clipped = apply_clipping(x_int8, col_mask, l, h)
    return nat, subprecision_sparsity(clipped)
