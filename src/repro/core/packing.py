"""SPARQLe packed sub-precision wire format (the storage layout, for real).

``core/sparqle.py`` decomposes int8 activations into nibble *planes* carried
in full int8 containers — convenient for kernels, but the bytes it moves are
dense-int8 bytes. This module is the actual wire format the paper's Eq. 1
accounts for, with exact pack/unpack inverses:

  * **LSB4 plane** — two nibbles per byte, row-major.  Byte ``j`` of a row
    holds column ``2j`` in its low nibble and column ``2j+1`` in its high
    nibble (the same convention as ``qlinear.pack_int4``).
  * **PBM words** — the precision bitmap folded into little-endian uint32
    words: bit ``i`` of word ``w`` is the PBM of column ``32*w + i``.
  * **MSB stream** — only the nonzero MSB4 nibbles, compacted in column
    order two-per-byte and indexed by the bitmap (nibble ``r`` of a row's
    stream belongs to the column of the row's ``r``-th set PBM bit).
    The device container is worst-case sized (K/2 bytes per row — JAX
    shapes are static); ``wire_bytes()`` measures the bytes actually
    occupied, ``ceil(popcount/2)`` per row.

**Padding rule:** the logical K axis is zero-padded up to a multiple of
``K_ALIGN = 32`` (the lcm of 2 nibbles/byte and 32 PBM bits/word) before
packing. Padded columns encode as value 0 with PBM 0, so they add LSB/PBM
container bytes (the "PBM-word rounding slack" vs Eq. 1) but no MSB stream
bytes, and ``decode_packed`` slices them back off exactly.

Kernels do not walk the bitmap-indexed stream (a 128-lane MXU tile needs
rectangular operands): ``kernels/sparqle_matmul.sparqle_matmul_packed``
consumes the two nibble planes packed two-per-byte (``pack_nibbles`` on
LSB4 and MSB4) and unpacks them in VMEM. ``planes_packed`` produces that
kernel operand form from a :class:`PackedSparqleActivation`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sparqle import SparqleActivation

PBM_WORD_BITS = 32
K_ALIGN = 32          # lcm(2 nibbles/byte, 32 PBM bits/word)

PLANE_WIDTHS = (1, 2, 4, 8)   # bit widths the parameterized codec supports


def pad_k(k: int) -> int:
    """Padded column count of the wire layout for a logical width ``k``."""
    return k + (-k) % K_ALIGN


def _pad_cols(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[-1]) % mult
    if not pad:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


# ---------------------------------------------------------------------------
# parameterized plane / bitmap primitives
# ---------------------------------------------------------------------------

def pack_plane(vals: jax.Array, *, width: int = 4) -> jax.Array:
    """(..., K mult of 8/width) values -> (..., K*width/8) bytes (int8).

    The width-``k`` generalization of the nibble packer: ``8/width``
    fields per byte, little-endian within the byte — field ``i`` of byte
    ``j`` (value index ``j*(8/width) + i``) occupies bits
    ``[i*width, (i+1)*width)``. Only the low ``width`` bits of each value
    travel, so signed (two's-complement) and unsigned fields pack alike.
    ``width=4`` reproduces :func:`pack_nibbles` exactly; ``width=8`` is
    the identity layout (one masked byte per value).
    """
    if width not in PLANE_WIDTHS:
        raise ValueError(f"width must be one of {PLANE_WIDTHS}, got {width}")
    per = 8 // width
    assert vals.shape[-1] % per == 0, (vals.shape, width)
    mask = (1 << width) - 1
    parts = [
        jnp.left_shift(
            jnp.bitwise_and(vals[..., i::per].astype(jnp.int32), mask),
            i * width)
        for i in range(per)
    ]
    acc = jnp.bitwise_and(functools.reduce(jnp.bitwise_or, parts), 0xFF)
    return jnp.where(acc > 127, acc - 256, acc).astype(jnp.int8)


def unpack_plane(packed: jax.Array, *, width: int = 4,
                 signed: bool) -> jax.Array:
    """Inverse of :func:`pack_plane`: (..., B) bytes -> (..., B*8/width)
    field values (int8). ``signed`` sign-extends each ``width``-bit field
    (two's-complement, range ``[-2^(width-1), 2^(width-1)-1]``); unsigned
    yields ``[0, 2^width - 1]``."""
    if width not in PLANE_WIDTHS:
        raise ValueError(f"width must be one of {PLANE_WIDTHS}, got {width}")
    per = 8 // width
    b = jnp.bitwise_and(packed.astype(jnp.int32), 0xFF)
    mask = (1 << width) - 1
    half = 1 << (width - 1)
    fields = []
    for i in range(per):
        f = jnp.bitwise_and(jnp.right_shift(b, i * width), mask)
        if signed:
            f = jnp.where(f >= half, f - (1 << width), f)
        fields.append(f)
    out = jnp.stack(fields, axis=-1)
    return out.reshape(*packed.shape[:-1],
                       packed.shape[-1] * per).astype(jnp.int8)


def pack_nibbles(nib: jax.Array) -> jax.Array:
    """(..., K even) nibble values -> (..., K/2) bytes (int8 container).

    Byte ``j`` = ``nib[2j] & 0xF  |  (nib[2j+1] & 0xF) << 4``. Works for
    unsigned LSB4 ([0, 15]) and two's-complement MSB4 ([-8, 7]) alike —
    only the low 4 bits of each value travel. Alias of
    :func:`pack_plane` at ``width=4``.
    """
    return pack_plane(nib, width=4)


def unpack_nibbles(packed: jax.Array, *, signed: bool) -> jax.Array:
    """Inverse of :func:`pack_nibbles`. ``signed`` sign-extends each nibble
    (MSB4 convention); unsigned yields values in [0, 15] (LSB4). Alias of
    :func:`unpack_plane` at ``width=4``."""
    return unpack_plane(packed, width=4, signed=signed)


def pack_pbm(pbm: jax.Array) -> jax.Array:
    """(..., K mult of 32) bool -> (..., K/32) uint32 bitmask words."""
    assert pbm.shape[-1] % PBM_WORD_BITS == 0, pbm.shape
    w = pbm.reshape(*pbm.shape[:-1], -1, PBM_WORD_BITS).astype(jnp.uint32)
    weights = jnp.left_shift(
        jnp.uint32(1), jnp.arange(PBM_WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(w * weights, axis=-1).astype(jnp.uint32)


def unpack_pbm(words: jax.Array, k: int) -> jax.Array:
    """Inverse of :func:`pack_pbm`, sliced to ``k`` logical columns."""
    bits = jnp.bitwise_and(
        jnp.right_shift(words[..., None],
                        jnp.arange(PBM_WORD_BITS, dtype=jnp.uint32)),
        jnp.uint32(1))
    flat = bits.reshape(*words.shape[:-1], words.shape[-1] * PBM_WORD_BITS)
    return flat[..., :k].astype(bool)


def compact_msb(msb4: jax.Array,
                pbm: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Compact the nonzero MSB4 nibbles into a bitmap-indexed stream.

    msb4/pbm (M, K) -> (stream (M, K/2) int8 two-nibbles-per-byte,
    count (M,) int32). The stream container is worst-case sized; nibbles
    past ``count`` are zero.
    """
    m, k = msb4.shape
    idx = jnp.cumsum(pbm, axis=1) - 1
    dest = jnp.where(pbm, idx, k)           # out-of-range writes dropped
    rows = jnp.arange(m)[:, None]
    nib = jnp.zeros((m, k), jnp.int8)
    nib = nib.at[rows, dest].set(
        jnp.bitwise_and(msb4, 0xF).astype(jnp.int8), mode="drop")
    return pack_nibbles(nib), jnp.sum(pbm, axis=1).astype(jnp.int32)


def expand_msb(stream: jax.Array, pbm: jax.Array) -> jax.Array:
    """Inverse of :func:`compact_msb`: scatter stream nibbles back to the
    dense (sign-extended) MSB4 plane using the bitmap."""
    m, k = pbm.shape
    nib = unpack_nibbles(stream, signed=True)           # (M, K) in [-8, 7]
    idx = jnp.clip(jnp.cumsum(pbm, axis=1) - 1, 0, k - 1)
    rows = jnp.arange(m)[:, None]
    return jnp.where(pbm, nib[rows, idx], 0).astype(jnp.int8)


# ---------------------------------------------------------------------------
# the packed activation pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedSparqleActivation:
    """An int8 activation tensor in the SPARQLe packed wire format.

    Arrays cover the K-padded layout (``pad_k(K)`` columns); ``shape`` is
    the logical (M, K) and is static pytree aux data.
    """

    lsb4: jax.Array        # (M, Kp/2) int8 — two LSB nibbles per byte
    pbm: jax.Array         # (M, Kp/32) uint32 bitmask words
    msb_stream: jax.Array  # (M, Kp/2) int8 — compacted MSB nibbles
    msb_count: jax.Array   # (M,) int32 — nibbles used in each row's stream
    scale: jax.Array       # f32 activation scale (as SparqleActivation)
    shape: Tuple[int, int] = (0, 0)

    def tree_flatten(self):
        return ((self.lsb4, self.pbm, self.msb_stream, self.msb_count,
                 self.scale), self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux)

    # -- measured accounting ----------------------------------------------

    def wire_bytes(self) -> jax.Array:
        """MEASURED bytes of this tensor on the wire (not container bytes):
        LSB plane + PBM words + ``ceil(popcount/2)`` stream bytes per row.
        Returns a jnp scalar (int-cast by host callers)."""
        m = self.lsb4.shape[0]
        lsb_b = m * self.lsb4.shape[-1]
        pbm_b = m * self.pbm.shape[-1] * 4
        msb_b = jnp.sum((self.msb_count + 1) // 2)
        return lsb_b + pbm_b + msb_b

    def container_bytes(self) -> int:
        """Bytes of the device containers (worst-case MSB stream)."""
        return int(self.lsb4.size + self.pbm.size * 4 + self.msb_stream.size
                   + self.msb_count.size * 4)

    def dense_bytes(self) -> int:
        """Bytes of the dense int8 tensor this encodes."""
        m, k = self.shape
        return m * k


def encode_packed(x_int8: jax.Array,
                  scale: jax.Array | float = 1.0) -> PackedSparqleActivation:
    """int8 (M, K) tensor -> packed wire format. Exact for all int8 input."""
    x = x_int8.astype(jnp.int8)
    assert x.ndim == 2, x.shape
    m, k = x.shape
    xp = _pad_cols(x, K_ALIGN)
    msb4 = jnp.right_shift(xp, 4)
    lsb4 = jnp.bitwise_and(xp, 0xF)
    pbm = msb4 != 0
    stream, count = compact_msb(msb4, pbm)
    return PackedSparqleActivation(
        lsb4=pack_nibbles(lsb4),
        pbm=pack_pbm(pbm),
        msb_stream=stream,
        msb_count=count,
        scale=jnp.asarray(scale, jnp.float32),
        shape=(m, k))


def decode_packed(p: PackedSparqleActivation) -> jax.Array:
    """Packed wire format -> int8 (M, K). Inverse of :func:`encode_packed`."""
    m, k = p.shape
    kp = p.lsb4.shape[-1] * 2
    pbm = unpack_pbm(p.pbm, kp)
    lsb4 = unpack_nibbles(p.lsb4, signed=False)
    msb4 = expand_msb(p.msb_stream, pbm)
    x = msb4.astype(jnp.int32) * 16 + lsb4.astype(jnp.int32)
    return x.astype(jnp.int8)[:, :k]


def planes_packed(p: PackedSparqleActivation) -> Tuple[jax.Array, jax.Array]:
    """Kernel operand form: (lsb4 packed, msb4 packed) dense nibble planes,
    both (M, Kp/2) two-per-byte — what ``sparqle_matmul_packed`` unpacks
    in VMEM. The MSB plane is re-expanded from the stream (rectangular
    operands; the bitmap-indexed stream is the storage/DMA format)."""
    kp = p.lsb4.shape[-1] * 2
    pbm = unpack_pbm(p.pbm, kp)
    msb4 = expand_msb(p.msb_stream, pbm)
    return p.lsb4, pack_nibbles(msb4)


def unpack_planes(p: PackedSparqleActivation) -> SparqleActivation:
    """Packed wire format -> the dense-plane :class:`SparqleActivation`
    (int8 containers), sliced to the logical shape."""
    m, k = p.shape
    kp = p.lsb4.shape[-1] * 2
    pbm = unpack_pbm(p.pbm, kp)
    return SparqleActivation(
        lsb4=unpack_nibbles(p.lsb4, signed=False)[:, :k],
        msb4=expand_msb(p.msb_stream, pbm)[:, :k],
        pbm=pbm[:, :k],
        scale=p.scale)


# ---------------------------------------------------------------------------
# lightweight measured accounting (telemetry hot paths)
# ---------------------------------------------------------------------------

def measured_wire_bytes_rows(q_int8: jax.Array) -> jax.Array:
    """Measured packed-wire bytes per row of an int8 tensor (..., K),
    WITHOUT running the codec: ``Kp/2 + 4*Kp/32 + ceil(popcount/2)``.
    Matches ``encode_packed(row).wire_bytes()`` exactly; cheap enough for
    per-layer serving telemetry inside jitted steps."""
    q = q_int8.astype(jnp.int8)
    k = q.shape[-1]
    kp = pad_k(k)
    nnz = jnp.sum((jnp.right_shift(q, 4) != 0).astype(jnp.int32), axis=-1)
    fixed = kp // 2 + (kp // PBM_WORD_BITS) * 4
    return fixed + (nnz + 1) // 2


def dense_bytes_rows(q_int8: jax.Array) -> int:
    """Dense int8 bytes per row (the baseline the wire format displaces)."""
    return q_int8.shape[-1]


def predicted_wire_bytes(n: int, sparsity: float, *, width: int = 4) -> float:
    """Generalized Eq. 1: predicted wire bytes for ``n`` int8 elements
    split into a dense ``width``-bit low plane, a 1-bit precision bitmap
    and a compacted ``(8-width)``-bit high plane at high-plane sparsity
    ``sparsity``::

        bytes = n * (width/8 + 1/8 + (1 - sparsity) * (8 - width)/8)

    ``width=4`` reproduces the paper's Eq. 1 exactly
    (``n * (1/2 + 1/8 + (1-s)/2)``); ``width=8`` degenerates to dense
    int8 plus the (useless) bitmap. The prediction ignores the PBM-word
    rounding slack and stream byte rounding the packed layout adds (see
    :func:`measured_wire_bytes_rows`).
    """
    if width not in PLANE_WIDTHS:
        raise ValueError(f"width must be one of {PLANE_WIDTHS}, got {width}")
    return n * (width / 8 + 1 / 8 + (1.0 - sparsity) * (8 - width) / 8)
