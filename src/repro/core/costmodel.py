"""Analytical energy/latency cost model of the SPARQLe accelerator (paper §4).

Faithful in *structure* to the paper's methodology:

  * iso-MAC comparison: 256 PEs, Int4xInt4 MACs, 2048 MACs/cycle for both the
    dense baseline and the SPARQLe hybrid accelerator (Table 1);
  * Int8 x Int4 = 2 compute rounds on Int4 MACs, Int8xInt8 = 4, Int4xInt4 /
    Int4xInt2 = 1 (paper §3.3 "compute rounds");
  * SPARQLe executes dense LSB4 pass (1 round) + sparse MSB4 pass
    ((1 - s) rounds, PBM-gated), sequentially on the shared MACs;
  * tiled output-stationary dataflow with load-compute-drain overlap
    (Fig. 5): per-layer latency = max(load, compute, drain) + pipeline fill;
  * activation traffic in SPARQLe format: 0.5 B (LSB4) + 1/8 B (PBM) +
    (1 - s) * 0.5 B (compressed MSB4) per element (Eq. 1); outputs drained
    already re-encoded (drain-path splitters + sparse encoder);
  * activation-activation ops (QK^T, softmax*V) and KV-cache traffic are
    modeled but NOT accelerated by SPARQLe (paper §5.1);
  * DRAM energy/latency excluded (paper §4); SRAM-level traffic only;
  * SPARQLe control overhead: +7 % power, +5.5 % area (paper §5.2).

The paper leaves several constants unspecified (SRAM-level tile reuse
factors, decode batch, per-op energies). These are explicit knobs on
:class:`HardwareConfig`; ``benchmarks/bench_costmodel.py --calibrate``
searches them to fit the paper's 12 reported improvement numbers and the
committed defaults are the best fit (see EXPERIMENTS.md §Cost-model).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class HardwareConfig:
    """Table 1 + inferred dataflow/energy knobs (7nm estimates)."""

    n_pes: int = 256
    macs_per_cycle: int = 2048           # Int4xInt4 MACs
    freq_ghz: float = 1.0
    sram_bytes: int = int(1.5 * 2**20)
    load_bw: float = 32.0                # B/cycle SRAM -> circular buffers
    drain_bw: float = 32.0               # B/cycle write-combine -> SRAM
    # SRAM-level tile reuse (inferred; fit by bench_costmodel --calibrate
    # against the paper's 12 reported improvements, RMSE 4.2pp):
    tile_m: int = 128                    # act rows resident -> weight reuse M/tile_m
    tile_n: int = 128                    # out cols resident -> act reuse N/tile_n
    # 7nm energy constants (pJ):
    e_mac_int4: float = 0.08             # per Int4xInt4 MAC
    e_sram_byte: float = 1.3             # per byte SRAM<->buffers
    e_rf_byte: float = 0.08              # per byte buffer<->RF
    leak_pj_per_cycle: float = 400.0     # array leakage+clock (calibrated)
    # SPARQLe overheads (paper §5.2):
    sparqle_power_ovh: float = 1.07
    sparqle_area_ovh: float = 1.055
    pipeline_fill_cycles: int = 64
    # system-level roofline peaks (per chip; TPU-v5e-class reference):
    # live attribution (obs/attribution.py) and benchmarks/roofline.py
    # normalize achieved FLOP/s, HBM bytes/s and interconnect bytes/s
    # against these — they describe the serving substrate, not the §4
    # SRAM-level accelerator modeled by the knobs above
    peak_flops: float = 197e12           # FLOP/s
    hbm_bw: float = 819e9                # B/s
    link_bw: float = 50e9                # B/s per ICI link


@dataclasses.dataclass
class LinearShape:
    """One matmul A(M,K) @ W(K,N); ``s`` = MSB4 sparsity of its input acts."""

    name: str
    m: int
    k: int
    n: int
    w_bits: int = 4
    s: float = 0.0                      # sub-precision sparsity of input acts
    sparqle_eligible: bool = True       # False for act x act (QK^T, PV)
    a_bits: int = 8                     # activation operand width
    count: int = 1                      # how many identical instances


@dataclasses.dataclass
class PhaseCost:
    cycles: float
    energy_pj: float
    load_bytes: float
    compute_macs: float
    drain_bytes: float

    @property
    def latency_us(self):
        return self.cycles / 1e3  # at 1 GHz, cycles -> ns; /1e3 -> us

    def __add__(self, o: "PhaseCost") -> "PhaseCost":
        return PhaseCost(
            self.cycles + o.cycles,
            self.energy_pj + o.energy_pj,
            self.load_bytes + o.load_bytes,
            self.compute_macs + o.compute_macs,
            self.drain_bytes + o.drain_bytes,
        )


ZERO = PhaseCost(0.0, 0.0, 0.0, 0.0, 0.0)


def _act_bytes_per_elem(sparqle: bool, s: float, a_bits: int,
                        lsb_only: bool = False) -> float:
    if not sparqle:
        return a_bits / 8.0
    half = a_bits / 16.0               # p/2 bits -> bytes
    if lsb_only:
        return half                    # draft streams the LSB plane alone
    return half + 1.0 / 8.0 + (1.0 - s) * half  # LSB + PBM + compressed MSB


def linear_cost(
    shape: LinearShape, hw: HardwareConfig, sparqle: bool,
    lsb_only: bool = False
) -> PhaseCost:
    """Cost of one tiled linear layer execution (one of ``count``).

    ``lsb_only`` models the self-speculative *draft* forward: the sparse
    MSB4 pass is statically elided, so an eligible linear costs exactly
    1 compute round (vs 1 + (1 - s) for the full hybrid pass) and streams
    only the LSB plane (p/2 bits/elem — no PBM, no compacted MSB).
    """
    m, k, n = shape.m, shape.k, shape.n
    macs = m * k * n
    use_sparqle = sparqle and shape.sparqle_eligible and shape.a_bits == 8
    draft = lsb_only and use_sparqle

    # ---- compute rounds on Int4 MACs (paper §3.3) ----
    base_rounds = max(1, shape.a_bits // 4)  # int8 ops take 2 rounds
    if draft:
        rounds = 1.0                         # dense LSB4 pass only
    elif use_sparqle:
        rounds = 1.0 + (1.0 - shape.s)       # dense LSB4 + sparse MSB4
    else:
        rounds = float(base_rounds)
    compute_cycles = rounds * macs / hw.macs_per_cycle

    # ---- SRAM-level traffic with tiled reuse ----
    n_reload = max(1.0, n / hw.tile_n)       # act reloads across N tiles
    m_reload = max(1.0, m / hw.tile_m)       # weight reloads across M tiles
    a_bpe = _act_bytes_per_elem(use_sparqle, shape.s, shape.a_bits, draft)
    act_bytes = m * k * n_reload * a_bpe
    w_bytes = k * n * m_reload * (shape.w_bits / 8.0)
    load_bytes = act_bytes + w_bytes
    # outputs drained re-encoded (SPARQLe) or int8 (baseline); the draft
    # drains LSB-only re-encoded streams too
    out_bpe = _act_bytes_per_elem(use_sparqle, shape.s, 8, draft)
    drain_bytes = m * n * out_bpe

    load_cycles = load_bytes / hw.load_bw
    drain_cycles = drain_bytes / hw.drain_bw
    cycles = max(load_cycles, compute_cycles, drain_cycles) + hw.pipeline_fill_cycles

    # ---- energy ----
    mac_energy = rounds * macs * hw.e_mac_int4
    sram_energy = (load_bytes + drain_bytes) * hw.e_sram_byte
    rf_energy = rounds * macs * 2 * hw.e_rf_byte * 0.5  # two nibble operands/MAC
    energy = mac_energy + sram_energy + rf_energy + cycles * hw.leak_pj_per_cycle
    if use_sparqle:
        energy *= hw.sparqle_power_ovh  # sparsity-logic power overhead

    return PhaseCost(cycles, energy, load_bytes, macs * rounds, drain_bytes)


def phase_cost(
    layers: List[LinearShape], hw: HardwareConfig, sparqle: bool,
    lsb_only: bool = False
) -> PhaseCost:
    """Sequential multi-layer execution (paper §4: 'modeled as sequential')."""
    total = ZERO
    for l in layers:
        c = linear_cost(l, hw, sparqle, lsb_only)
        total = total + PhaseCost(
            c.cycles * l.count, c.energy_pj * l.count,
            c.load_bytes * l.count, c.compute_macs * l.count,
            c.drain_bytes * l.count,
        )
    return total


# ---------------------------------------------------------------------------
# Model descriptions: per-layer linear lists for the paper's three models
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LMShape:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    w_bits: int = 4
    gated_mlp: bool = True               # SwiGLU: gate+up+down


PAPER_MODELS: Dict[str, LMShape] = {
    # BitNet b1.58 3B (paper [15]): 26L, d=3200, ff=8640, W2A8KV4
    "bitnet-3b": LMShape("bitnet-3b", 26, 3200, 32, 32, 8640, 32002, w_bits=2),
    # Llama2-7B (QServe W4A8KV4)
    "llama2-7b": LMShape("llama2-7b", 32, 4096, 32, 32, 11008, 32000, w_bits=4),
    # Llama3-8B (QServe W4A8KV4)
    "llama3-8b": LMShape("llama3-8b", 32, 4096, 32, 8, 14336, 128256, w_bits=4),
}


def lm_linear_layers(
    model: LMShape,
    m_tokens: int,
    s_linear: float,
    *,
    seq_for_attn: int,
    decode: bool,
    per_layer_s: Optional[List[Dict[str, float]]] = None,
) -> List[LinearShape]:
    """Expand an LM into its per-decoder-block linears + act-act attention ops.

    ``m_tokens``: rows of every linear (prefill: seq*batch; decode: batch).
    ``seq_for_attn``: KV length for the attention score/value ops.
    ``per_layer_s``: optional per-layer, per-projection sparsity overrides
    (keys: q/k/v/o/gate/up/down), used for the Fig. 8 layerwise benchmark.
    """
    d, h, kvh = model.d_model, model.n_heads, model.n_kv_heads
    hd = d // h
    layers: List[LinearShape] = []
    for li in range(model.n_layers):
        sl = (per_layer_s[li] if per_layer_s is not None else {})
        g = lambda key: sl.get(key, s_linear)  # noqa: E731
        layers += [
            LinearShape(f"L{li}.q_proj", m_tokens, d, d, model.w_bits, g("q")),
            LinearShape(f"L{li}.k_proj", m_tokens, d, kvh * hd, model.w_bits, g("k")),
            LinearShape(f"L{li}.v_proj", m_tokens, d, kvh * hd, model.w_bits, g("v")),
            LinearShape(f"L{li}.o_proj", m_tokens, d, d, model.w_bits, g("o")),
            LinearShape(f"L{li}.gate_proj", m_tokens, d, model.d_ff, model.w_bits, g("gate")),
            LinearShape(f"L{li}.up_proj", m_tokens, d, model.d_ff, model.w_bits, g("up")),
            LinearShape(f"L{li}.down_proj", m_tokens, model.d_ff, d, model.w_bits, g("down")),
        ]
        # act x act attention ops: QK^T and P·V, with int4 KV cache (KV4).
        # Not SPARQLe-eligible (paper §5.1). Weights here *are* the KV cache.
        layers += [
            LinearShape(f"L{li}.qkT", m_tokens * h, hd, seq_for_attn,
                        w_bits=4, s=0.0, sparqle_eligible=False),
            LinearShape(f"L{li}.pv", m_tokens * h, seq_for_attn, hd,
                        w_bits=4, s=0.0, sparqle_eligible=False),
        ]
    layers.append(
        LinearShape("lm_head", m_tokens, d, model.vocab, model.w_bits, s_linear)
    )
    return layers


@dataclasses.dataclass
class InferenceReport:
    model: str
    prefill_base: PhaseCost
    prefill_sparqle: PhaseCost
    decode_base: PhaseCost
    decode_sparqle: PhaseCost

    def improvements(self) -> Dict[str, float]:
        pct = lambda b, s: (1.0 - s / b) * 100.0  # noqa: E731
        return {
            "ttft_latency_pct": pct(self.prefill_base.cycles, self.prefill_sparqle.cycles),
            "tpot_latency_pct": pct(self.decode_base.cycles, self.decode_sparqle.cycles),
            "prefill_energy_pct": pct(self.prefill_base.energy_pj, self.prefill_sparqle.energy_pj),
            "decode_energy_pct": pct(self.decode_base.energy_pj, self.decode_sparqle.energy_pj),
            "prefill_transfer_pct": pct(
                self.prefill_base.load_bytes + self.prefill_base.drain_bytes,
                self.prefill_sparqle.load_bytes + self.prefill_sparqle.drain_bytes),
            "decode_transfer_pct": pct(
                self.decode_base.load_bytes + self.decode_base.drain_bytes,
                self.decode_sparqle.load_bytes + self.decode_sparqle.drain_bytes),
            "prefill_compute_pct": pct(self.prefill_base.compute_macs,
                                       self.prefill_sparqle.compute_macs),
            "decode_compute_pct": pct(self.decode_base.compute_macs,
                                      self.decode_sparqle.compute_macs),
        }


def evaluate_model(
    model: LMShape,
    s_linear: float,
    hw: Optional[HardwareConfig] = None,
    *,
    prefill_tokens: int = 2048,
    decode_batch: int = 16,
    decode_kv_len: int = 2048,
    per_layer_s: Optional[List[Dict[str, float]]] = None,
) -> InferenceReport:
    """TTFT/TPOT + energy for baseline dense accel vs SPARQLe accel."""
    hw = hw or HardwareConfig()
    prefill = lm_linear_layers(model, prefill_tokens, s_linear,
                               seq_for_attn=prefill_tokens, decode=False,
                               per_layer_s=per_layer_s)
    decode = lm_linear_layers(model, decode_batch, s_linear,
                              seq_for_attn=decode_kv_len, decode=True,
                              per_layer_s=per_layer_s)
    return InferenceReport(
        model=model.name,
        prefill_base=phase_cost(prefill, hw, sparqle=False),
        prefill_sparqle=phase_cost(prefill, hw, sparqle=True),
        decode_base=phase_cost(decode, hw, sparqle=False),
        decode_sparqle=phase_cost(decode, hw, sparqle=True),
    )


def area_power_overhead(hw: Optional[HardwareConfig] = None) -> Dict[str, float]:
    """§5.2 accounting: overheads of the hybrid PE vs iso-MAC dense baseline."""
    hw = hw or HardwareConfig()
    return {
        "area_overhead_pct": (hw.sparqle_area_ovh - 1.0) * 100.0,
        "power_overhead_pct": (hw.sparqle_power_ovh - 1.0) * 100.0,
    }


# ---------------------------------------------------------------------------
# Self-speculative decoding (serving/spec_decode.py): analytical win region
# ---------------------------------------------------------------------------

def expected_tokens_per_step(alpha: float, gamma: int) -> float:
    """E[tokens emitted per draft+verify cycle] under per-token acceptance
    probability ``alpha`` with a γ-token greedy draft window:
    sum_{k=0}^{γ} α^k (k accepted drafts + the correction/bonus token)."""
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(alpha)
    return sum(alpha ** k for k in range(gamma + 1))


@dataclasses.dataclass
class SpeculativeReport:
    """Analytical TPOT of γ-draft self-speculative decoding vs sequential.

    One speculative cycle = γ single-token LSB4-only draft steps (1 compute
    round per eligible linear) + one (γ+1)-token batched full-precision
    verify step (1 + (1 - s) rounds), amortized over E[tokens/cycle].
    """

    model: str
    gamma: int
    alpha: float                       # per-token draft acceptance prob
    s: float                           # MSB4 sparsity feeding the costs
    draft_step: PhaseCost              # ONE single-token LSB-only step
    verify_step: PhaseCost             # ONE (γ+1)-token batched full step
    baseline_step: PhaseCost           # ONE non-speculative full step

    @property
    def expected_tokens(self) -> float:
        return expected_tokens_per_step(self.alpha, self.gamma)

    @property
    def spec_cycles_per_token(self) -> float:
        cyc = self.gamma * self.draft_step.cycles + self.verify_step.cycles
        return cyc / self.expected_tokens

    @property
    def baseline_cycles_per_token(self) -> float:
        return self.baseline_step.cycles

    @property
    def tpot_speedup(self) -> float:
        """> 1.0 means γ-drafting wins on decode latency."""
        return self.baseline_cycles_per_token / self.spec_cycles_per_token

    @property
    def spec_energy_per_token(self) -> float:
        e = self.gamma * self.draft_step.energy_pj + self.verify_step.energy_pj
        return e / self.expected_tokens

    def improvements(self) -> Dict[str, float]:
        return {
            "tpot_speedup": self.tpot_speedup,
            "tpot_latency_pct": (1.0 - self.spec_cycles_per_token
                                 / self.baseline_cycles_per_token) * 100.0,
            "decode_energy_pct": (1.0 - self.spec_energy_per_token
                                  / self.baseline_step.energy_pj) * 100.0,
            "expected_tokens_per_step": self.expected_tokens,
        }


def evaluate_speculative(
    model: LMShape,
    s: float,
    gamma: int,
    alpha: float,
    hw: Optional[HardwareConfig] = None,
    *,
    decode_batch: int = 16,
    decode_kv_len: int = 2048,
) -> SpeculativeReport:
    """Speculative vs sequential decode on the SPARQLe accelerator.

    ``s`` is the measured MSB4 sparsity (drives the verify/baseline round
    count 1 + (1 - s) and the wire bytes); ``alpha`` the measured per-token
    draft acceptance rate (``Request.stats()['spec_acceptance_rate']``).
    The verify step batches γ+1 window tokens per sequence, so its linears
    see ``decode_batch * (γ+1)`` rows while attention still walks the same
    KV length.
    """
    if gamma < 1:
        raise ValueError(gamma)
    hw = hw or HardwareConfig()
    one_tok = lm_linear_layers(model, decode_batch, s,
                               seq_for_attn=decode_kv_len, decode=True)
    window = lm_linear_layers(model, decode_batch * (gamma + 1), s,
                              seq_for_attn=decode_kv_len, decode=True)
    return SpeculativeReport(
        model=model.name, gamma=gamma, alpha=alpha, s=s,
        draft_step=phase_cost(one_tok, hw, sparqle=True, lsb_only=True),
        verify_step=phase_cost(window, hw, sparqle=True),
        baseline_step=phase_cost(one_tok, hw, sparqle=True),
    )


def breakeven_acceptance(
    model: LMShape,
    s: float,
    gamma: int,
    hw: Optional[HardwareConfig] = None,
    *,
    decode_batch: int = 16,
    decode_kv_len: int = 2048,
    tol: float = 1e-4,
) -> float:
    """Minimum per-token acceptance rate at which γ-drafting wins.

    Bisects α in [0, 1] for ``tpot_speedup == 1``; returns ``inf`` when
    even α = 1 loses (the draft+verify overhead exceeds the window) and
    0 when α = 0 already wins (possible when batching the verify step is
    itself cheaper per token than sequential decode). This is the
    cost-model answer to "when does LSB4-only drafting pay off?" as a
    function of the measured MSB sparsity ``s``.
    """
    rep = evaluate_speculative(model, s, gamma, 1.0, hw,
                               decode_batch=decode_batch,
                               decode_kv_len=decode_kv_len)
    if rep.tpot_speedup < 1.0:
        return float("inf")
    lo, hi = 0.0, 1.0
    if dataclasses.replace(rep, alpha=0.0).tpot_speedup >= 1.0:
        return 0.0
    while hi - lo > tol:
        mid = (lo + hi) / 2.0
        if dataclasses.replace(rep, alpha=mid).tpot_speedup >= 1.0:
            hi = mid
        else:
            lo = mid
    return hi


# Paper-reported operating points (§5.1), used by calibration & validation.
PAPER_SPARSITY = {"bitnet-3b": 0.618, "llama2-7b": 0.470, "llama3-8b": 0.444}
PAPER_CLAIMS = {
    # model: (ttft%, tpot%, prefill_E%, decode_E%)
    "bitnet-3b": (24.3, 23.4, 26.7, 14.2),
    "llama2-7b": (17.2, 14.6, 18.4, 7.1),
    "llama3-8b": (16.0, 13.5, 17.0, 6.5),
}
