"""Integer quantization substrate: W4A8 / W2A8 / KV4 (paper §4 model configs).

Symmetric per-output-channel weight quantization (int4 / ternary int2),
per-token (or per-tensor) int8 activation quantization with optional
zero-point adjustment (paper §3.1: shifting non-centered distributions into
the MSB4==0 range), and int4 KV-cache quantization (W4A8KV4 / W2A8KV4).

Quantized payloads are carried in int8 containers at this level; sub-byte
packing is applied downstream where the bytes move — weights via
``qlinear.pack_int4``, the KV cache via ``model._kv_quant``, and the
activation stream via the packed wire format in ``core/packing.py``
(docs/format.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """q * scale + zero  ≈  original  (zero is in real units, optional)."""

    q: jax.Array          # int8 container
    scale: jax.Array      # f32, broadcastable to q
    zero: jax.Array       # f32, broadcastable to q (0.0 when symmetric)
    bits: int             # payload width actually used (2, 4, or 8)

    def tree_flatten(self):
        return (self.q, self.scale, self.zero), self.bits

    @classmethod
    def tree_unflatten(cls, bits, children):
        return cls(*children, bits=bits)

    @property
    def shape(self):
        return self.q.shape

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale + self.zero


def _qrange(bits: int) -> tuple[int, int]:
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def quantize_weights(w: jax.Array, bits: int = 4, axis: int = -1) -> QuantizedTensor:
    """Symmetric per-channel weight quantization.

    ``axis`` is the *reduction* axis of the matmul the weight participates in;
    scales are computed per output channel (all axes except ``axis`` reduced).
    For bits=2 this is ternary-ish {-2..1} (BitNet W2 carrier).
    """
    lo, hi = _qrange(bits)
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax / hi, 1e-8)
    q = jnp.clip(jnp.round(w / scale), lo, hi).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32),
                           zero=jnp.zeros_like(scale, jnp.float32), bits=bits)


def quantize_activations(
    x: jax.Array,
    bits: int = 8,
    per_token: bool = True,
    zero_point: bool = False,
    amax: Optional[jax.Array] = None,
) -> QuantizedTensor:
    """Int8 activation quantization.

    ``zero_point=True`` applies the paper's zero-point adjustment: shift the
    distribution so its near-zero mass lands in [0, 15] (MSB4==0 range),
    boosting sub-precision sparsity for non-centered activations (e.g. SiLU
    outputs). The shift is in real units; dequantization undoes it exactly.

    ``amax`` overrides the reduction-axis abs-max (broadcastable to the
    keepdims reduction shape). A tensor-parallel caller whose rows are
    sharded over a mesh axis passes the GLOBAL row max (an exact ``pmax``
    of local maxima), so every shard quantizes with the same scale and
    the local int8 planes are exact slices of the unsharded ones.
    """
    lo, hi = _qrange(bits)
    axis = tuple(range(x.ndim - 1, x.ndim)) if per_token else tuple(range(x.ndim))
    if zero_point:
        assert amax is None, "amax override not supported with zero_point"
        # Paper §3.1 zero-point adjustment: shift so the distribution's
        # near-minimum mass lands at q ~ 0, i.e. inside the MSB4==0 range
        # [0, 15]. For SiLU-like activations (bounded slightly below zero,
        # mode near zero) this converts the dense near-zero band into
        # sub-precision-sparse codes, at the cost of using only the
        # non-negative half of the int8 range for the payload.
        xmin = jnp.min(x, axis=axis, keepdims=True)
        xmax = jnp.max(x, axis=axis, keepdims=True)
        scale = jnp.maximum((xmax - xmin) / hi, 1e-8)
        zero = xmin                       # x == xmin -> q == 0
        q = jnp.clip(jnp.round((x - zero) / scale), 0, hi).astype(jnp.int8)
        return QuantizedTensor(q=q, scale=scale.astype(jnp.float32),
                               zero=zero.astype(jnp.float32), bits=bits)
    if amax is None:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax / hi, 1e-8)
    q = jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32),
                           zero=jnp.zeros_like(scale, jnp.float32), bits=bits)


def quantize_kv(kv: jax.Array, bits: int = 4) -> QuantizedTensor:
    """KV-cache quantization (per head-dim-channel scales), KV4 in the paper."""
    return quantize_weights(kv, bits=bits, axis=-1)


def dequantize(t: QuantizedTensor) -> jax.Array:
    return t.dequantize()


def fake_quantize(x: jax.Array, bits: int = 8, per_token: bool = True) -> jax.Array:
    """Quantize-dequantize in one op (QAT-style straight-through in fwd)."""
    return quantize_activations(x, bits=bits, per_token=per_token).dequantize()
