"""SPARQLe activation codec (paper §3.1).

Decomposes an int8 activation tensor into the three structured components of
the SPARQLe representation:

  * ``lsb4`` — dense tensor of the low 4 bits of every element (values 0..15,
    carried in an int8 container),
  * ``pbm``  — precision bitmap, ``True`` where the element's MSB4 is nonzero,
  * ``msb4`` — the arithmetic high nibble (values -8..7, int8 container).

Numerical identity (two's complement):  ``x == (x >> 4) * 16 + (x & 0xF)``.

This module is the *plane-level* codec: full int8 containers, convenient
for kernels and tests. The actual wire format — LSB4 two-per-byte, PBM
folded into uint32 words, MSB4 compacted into a bitmap-indexed stream —
lives in ``core/packing.py`` (see docs/format.md), with measured
``wire_bytes()`` accounting and packed Pallas kernel variants
(``kernels/sparqle_{encode,matmul}.py``). ``encoded_bytes`` below is the
analytical Eq. 1 *prediction* the measured bytes are benchmarked against
(compression% = (4s-1)/8 * 100 for p=8).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# MSB4==0 range for two's-complement int8 (paper §3.2): [lp_l, lp_h].
LP_LOW = 0
LP_HIGH = 15


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparqleActivation:
    """A SPARQLe-decomposed int8 activation tensor.

    All planes share the logical shape of the source tensor. ``scale`` is the
    activation quantization scale that maps int8 back to real values (kept
    with the payload so downstream matmuls can rescale outputs).
    """

    lsb4: jax.Array  # int8 container, values in [0, 15]
    msb4: jax.Array  # int8 container, values in [-8, 7], zero where pbm==0
    pbm: jax.Array   # bool
    scale: jax.Array  # f32, per-token or per-tensor activation scale

    def tree_flatten(self):
        return (self.lsb4, self.msb4, self.pbm, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def shape(self):
        return self.lsb4.shape


def encode(x_int8: jax.Array, scale: jax.Array | float = 1.0) -> SparqleActivation:
    """int8 tensor -> (LSB4, MSB4, PBM). Exact for all int8 inputs."""
    x = x_int8.astype(jnp.int8)
    msb4 = jnp.right_shift(x, 4)          # arithmetic shift: sign-extends
    lsb4 = jnp.bitwise_and(x, 0xF)        # low nibble, 0..15
    pbm = msb4 != 0
    return SparqleActivation(
        lsb4=lsb4.astype(jnp.int8),
        msb4=msb4.astype(jnp.int8),
        pbm=pbm,
        scale=jnp.asarray(scale, jnp.float32),
    )


def decode(a: SparqleActivation) -> jax.Array:
    """(LSB4, MSB4, PBM) -> int8 tensor. Inverse of :func:`encode`."""
    x = a.msb4.astype(jnp.int32) * 16 + a.lsb4.astype(jnp.int32)
    return x.astype(jnp.int8)


def subprecision_sparsity(x_int8: jax.Array, axis=None) -> jax.Array:
    """Fraction ``s`` of elements whose MSB4 is zero (i.e. value in [0, 15]).

    ``axis`` as in ``jnp.mean``: None reduces to a scalar (the paper's
    tensor-level s); ``axis=-1`` gives per-token sparsity for telemetry.
    """
    msb4 = jnp.right_shift(x_int8.astype(jnp.int8), 4)
    return jnp.mean((msb4 == 0).astype(jnp.float32), axis=axis)


def compression_percent(s: jax.Array | float, p: int = 8) -> jax.Array:
    """Paper Eq. 1. Storage saved vs a dense p-bit tensor.

    dense p bits/elem vs (p/2 LSB bits + 1 PBM bit + (1-s)*p/2 MSB bits).
    For p=8 this evaluates to (4s-1)/8 * 100.
    """
    s = jnp.asarray(s, jnp.float32)
    kept = p / 2 + 1 + (1 - s) * p / 2
    return (p - kept) / p * 100.0


def ops_reduction_percent(s: jax.Array | float) -> jax.Array:
    """Paper Eq. 2: fraction of int4-MAC work skipped by the sparse pass."""
    return jnp.asarray(s, jnp.float32) / 2.0 * 100.0


def encoded_bytes(shape: Tuple[int, ...], s: float, p: int = 8) -> float:
    """Eq. 1 analytical *prediction* of the compressed wire bytes for an
    ``s``-sparse tensor. The measured counterpart is
    ``packing.PackedSparqleActivation.wire_bytes()`` (the two differ by
    the PBM-word / stream-byte rounding slack)."""
    n = 1
    for d in shape:
        n *= d
    bits = n * (p / 2 + 1 + (1 - s) * p / 2)
    return bits / 8.0


def tile_population(pbm: jax.Array, tile_m: int, tile_k: int) -> jax.Array:
    """Per-(M-tile, K-tile) nonzero-MSB4 population counts.

    This is the TPU-side co-design artifact (DESIGN.md §2): the Pallas kernel
    predicates the sparse MSB4 pass per VMEM tile on ``population > 0``.
    ``pbm`` is (M, K); returns int32 (M/tile_m, K/tile_k). Requires divisible
    shapes (callers pad — kernels always operate on tile-aligned operands).
    """
    m, k = pbm.shape
    assert m % tile_m == 0 and k % tile_k == 0, (pbm.shape, tile_m, tile_k)
    t = pbm.reshape(m // tile_m, tile_m, k // tile_k, tile_k)
    return t.sum(axis=(1, 3)).astype(jnp.int32)


def tile_sparsity(pbm: jax.Array, tile_m: int, tile_k: int) -> jax.Array:
    """Fraction of (tile_m x tile_k) MSB4 tiles that are entirely zero."""
    pop = tile_population(pbm, tile_m, tile_k)
    return jnp.mean((pop == 0).astype(jnp.float32))
