"""Deterministic synthetic token pipeline: shardable, packed, restartable.

No external datasets ship with the container, so the pipeline synthesizes a
structured token stream (a Zipf-distributed Markov chain with local n-gram
structure) that a small LM can measurably learn — enough signal for the
end-to-end training example and the accuracy benchmarks.

Design mirrors a production loader:
  * *stateless indexing* — ``batch_at(step)`` is a pure function of
    (seed, step), so a restarted job resumes mid-epoch with zero drift and
    any host can materialize exactly its own shard (``host_slice``);
  * *sequence packing* — documents of random length are packed back-to-back
    with EOS separators, matching how LM pretraining batches are built;
  * *sharding* — batches are produced host-locally and placed onto the
    global mesh with ``jax.make_array_from_process_local_data`` in the
    multi-host path (single-host: ``jax.device_put``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 512
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 1234
    eos_id: int = 0
    mean_doc_len: int = 96
    zipf_a: float = 1.3


class SyntheticLM:
    """Zipf-Markov synthetic language with deterministic per-step batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # fixed random "grammar": each token has a small successor set
        self.n_succ = 8
        self.succ = rng.integers(1, v, size=(v, self.n_succ), dtype=np.int32)
        # Zipf-ish unigram over successor slots
        p = 1.0 / np.arange(1, self.n_succ + 1) ** cfg.zipf_a
        self.slot_p = (p / p.sum()).astype(np.float64)

    def _doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        v = self.cfg.vocab
        out = np.empty(length, np.int32)
        t = int(rng.integers(1, v))
        for i in range(length):
            out[i] = t
            slot = rng.choice(self.n_succ, p=self.slot_p)
            t = int(self.succ[t, slot])
        return out

    def _packed_row(self, row_seed: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(row_seed)
        toks: list = []
        while len(toks) < cfg.seq_len + 1:
            length = max(4, int(rng.exponential(cfg.mean_doc_len)))
            toks.extend(self._doc(rng, length).tolist())
            toks.append(cfg.eos_id)
        return np.asarray(toks[: cfg.seq_len + 1], np.int32)

    def batch_at(self, step: int,
                 host_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
        """Pure function of step -> {'tokens', 'targets'} (B, S)."""
        cfg = self.cfg
        rows = range(cfg.global_batch)[host_slice or slice(None)]
        packed = np.stack([
            self._packed_row(cfg.seed * 1_000_003 + step * cfg.global_batch + r)
            for r in rows])
        return {"tokens": packed[:, :-1], "targets": packed[:, 1:]}

    def iter_batches(self, start_step: int = 0) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict:
    """Place a host-local batch onto the mesh.

    ``shardings`` is a pytree of NamedShardings matching ``batch``. On a
    multi-host runtime each process passes only its local rows and this
    uses ``make_array_from_process_local_data``; single-host falls back to
    a plain sharded device_put.
    """
    if jax.process_count() > 1:
        return jax.tree_util.tree_map(
            lambda x, s: jax.make_array_from_process_local_data(s, x),
            batch, shardings)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(jnp.asarray(x), s), batch, shardings)
