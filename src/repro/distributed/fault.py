"""Fault tolerance: restartable step loop, straggler deadline, fault injection.

Large fleets fail constantly; the framework's contract is that a failed or
stuck *step* never loses more than the work since the last checkpoint:

  * ``RestartableLoop`` wraps the train step. Any exception inside a step
    (device error, injected fault, preemption signal) triggers restore from
    the newest complete checkpoint and replay from that step. Because the
    data pipeline is stateless-indexable (``batch_at(step)``), replay is
    bit-identical.
  * ``DeadlineMonitor`` is the straggler mitigation: a watchdog thread that
    raises in the main thread if a step exceeds ``deadline_s`` (hung
    collective / dead host). On real fleets the step deadline triggers the
    same restore path after the runtime reslices the job; here it is
    exercised in tests with ``FaultInjector``.
  * ``FaultInjector`` deterministically fails chosen steps (or sleeps to
    fake a straggler) so the recovery path is testable on one host.

Pass ``registry=`` (a ``repro.obs.MetricsRegistry``) to RestartableLoop
to mirror the ``LoopReport`` counters into named metrics — steps run,
faults, restarts, restores, checkpoints, plus a fault-time-lost gauge
(work redone: time between the restored-from checkpoint and the fault)
— so a serving/training job exposes recovery health on the same scrape
as everything else (docs/observability.md).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint import store


class StepFault(RuntimeError):
    """A step failed (injected or real)."""


class StragglerTimeout(RuntimeError):
    """A step exceeded its deadline."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault plan: {step: 'fail' | 'hang'}."""

    plan: Dict[int, str] = dataclasses.field(default_factory=dict)
    fired: Dict[int, str] = dataclasses.field(default_factory=dict)
    hang_s: float = 0.5

    def check(self, step: int) -> None:
        action = self.plan.get(step)
        if action and step not in self.fired:
            self.fired[step] = action
            if action == "fail":
                raise StepFault(f"injected failure at step {step}")
            if action == "hang":
                time.sleep(self.hang_s)


class DeadlineMonitor:
    """Watchdog: mark step start/end; a step running past ``deadline_s``
    flags a straggler, surfaced as StragglerTimeout at the next poll."""

    def __init__(self, deadline_s: float):
        self.deadline_s = deadline_s
        self._start: Optional[float] = None
        self._lock = threading.Lock()
        self.tripped = False

    def begin(self) -> None:
        with self._lock:
            self._start = time.monotonic()

    def end(self) -> None:
        with self._lock:
            if (self._start is not None
                    and time.monotonic() - self._start > self.deadline_s):
                self.tripped = True
            self._start = None

    def raise_if_tripped(self) -> None:
        if self.tripped:
            self.tripped = False
            raise StragglerTimeout(
                f"step exceeded {self.deadline_s}s deadline")


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    restores: int = 0
    faults_seen: int = 0


class RestartableLoop:
    """Checkpoint-restore step loop.

    ``step_fn(state, batch) -> (state, metrics)`` must be pure (jitted).
    ``make_batch(step)`` must be a pure function of the step index.
    """

    def __init__(
        self,
        step_fn: Callable[[Any, Any], Any],
        make_batch: Callable[[int], Any],
        ckpt_dir: str,
        *,
        ckpt_every: int = 50,
        max_restarts: int = 10,
        deadline_s: float = 1e9,
        injector: Optional[FaultInjector] = None,
        async_ckpt: bool = False,
        state_shardings: Optional[Any] = None,
        registry: Optional[Any] = None,
    ):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = DeadlineMonitor(deadline_s)
        self.injector = injector
        self.writer = (store.AsyncWriter(ckpt_dir) if async_ckpt else None)
        self.state_shardings = state_shardings
        self.report = LoopReport()
        self._last_ckpt_t: Optional[float] = None
        if registry is not None:
            self._m_steps = registry.counter(
                "fault_steps_run_total", "train steps completed by the "
                "restartable loop", unit="steps")
            self._m_faults = registry.counter(
                "fault_faults_total", "step faults seen (injected or "
                "real, incl. straggler deadline trips)", unit="faults")
            self._m_restarts = registry.counter(
                "fault_restarts_total", "successful restore-and-replay "
                "restarts", unit="restarts")
            self._m_restores = registry.counter(
                "fault_restores_total", "checkpoint restores performed",
                unit="restores")
            self._m_ckpts = registry.counter(
                "fault_checkpoints_total", "checkpoints written (sync "
                "and async submits)", unit="checkpoints")
            self._g_time_lost = registry.gauge(
                "fault_time_lost_seconds", "cumulative wall time redone: "
                "step work between the restored-from checkpoint and each "
                "fault", unit="seconds")
        else:
            self._m_steps = self._m_faults = self._m_restarts = None
            self._m_restores = self._m_ckpts = self._g_time_lost = None

    def _save(self, state: Any, step: int) -> None:
        if self.writer is not None:
            self.writer.submit(state, step)
        else:
            store.save(self.ckpt_dir, state, step)
        self._last_ckpt_t = time.monotonic()
        if self._m_ckpts is not None:
            self._m_ckpts.inc()

    def _restore_latest(self, like: Any):
        step = store.latest_step(self.ckpt_dir)
        if step is None:
            return None
        state = store.restore(self.ckpt_dir, step, like,
                              self.state_shardings)
        self.report.restores += 1
        if self._m_restores is not None:
            self._m_restores.inc()
        return step, state

    def run(self, state: Any, start_step: int, n_steps: int):
        """Run ``n_steps`` with checkpoint/restart. Returns (state, metrics
        of last step)."""
        step = start_step
        end = start_step + n_steps
        metrics = None
        restarts = 0
        # initial checkpoint so a step-0 failure is recoverable
        if store.latest_step(self.ckpt_dir) is None:
            self._save(state, step)
        while step < end:
            try:
                self.monitor.begin()
                if self.injector is not None:
                    self.injector.check(step)
                batch = self.make_batch(step)
                state, metrics = self.step_fn(state, batch)
                self.monitor.end()
                self.monitor.raise_if_tripped()
                step += 1
                self.report.steps_run += 1
                if self._m_steps is not None:
                    self._m_steps.inc()
                if step % self.ckpt_every == 0:
                    self._save(state, step)
            except (StepFault, StragglerTimeout) as e:
                self.report.faults_seen += 1
                if self._m_faults is not None:
                    self._m_faults.inc()
                    if self._last_ckpt_t is not None:
                        self._g_time_lost.inc(
                            time.monotonic() - self._last_ckpt_t)
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                restored = self._restore_latest(state)
                if restored is None:
                    raise
                step, state = restored
                self.report.restarts += 1
                if self._m_restarts is not None:
                    self._m_restarts.inc()
        self._save(state, step)          # final checkpoint
        if self.writer is not None:
            self.writer.close()
            self.writer = None
        return state, metrics
