"""Tensor-parallel serving support: shard the SPARQLe stack over a mesh.

The serving engine's jitted steps become *mesh-native* by wrapping the
exact single-device step bodies in ``shard_map`` with

  * weights partitioned on the ``"model"`` axis following the Megatron
    column/row pattern (the physical realization of the logical-axis rule
    table in ``distributed/sharding.py``: ``heads``/``kv_heads``/``mlp``/
    ``vocab`` -> ``"model"``), and
  * the paged KV pool sharded on ``kv_heads`` over ``"model"`` and on the
    new ``pages`` logical axis over ``"data"`` (request-level parallelism
    — each data shard owns a slab of pages and a slice of decode slots).

Bit-exactness contract (what makes sharded greedy streams byte-identical
to the single-device engine): SPARQLe projections accumulate in *int32*.
A row-parallel (K-sharded) linear therefore

  1. computes its per-token activation scale from the GLOBAL row via an
     exact ``pmax`` over the model axis (max is order-independent),
  2. quantizes/clips/decomposes locally — the local int8/nibble planes are
     exact slices of the single-device planes, and
  3. reduces the merged dual-pass accumulator with ONE int32 ``psum``
     (LSB and shifted MSB partials summed together, not per-pass) —
     integer addition is associative, so the reduced accumulator equals
     the single-device accumulator bit for bit; the f32 rescale then
     multiplies identical operands.

Column-parallel linears are exact by construction (each shard computes an
untouched slice of the output channels). The trace-time :func:`tp_scope`
context tells ``core/qlinear.py`` which mesh axis to reduce over; model
code only marks *which* call sites are row-parallel (``tp="row"``) — the
markers are inert outside a TP trace, so the same model code serves the
single-device path unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, List, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# trace-time TP context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPContext:
    axis: str = "model"              # mesh axis of the weight partition
    ways: int = 1                    # its size (1 = no model parallelism)
    batch_axis: Optional[str] = None  # mesh axis the decode batch is
    #                                   sharded over (None in prefill: the
    #                                   chunk is replicated across data)


class _TPState(threading.local):
    def __init__(self):
        self.ctx: Optional[TPContext] = None


_TP = _TPState()


@contextlib.contextmanager
def tp_scope(axis: str, ways: int, batch_axis: Optional[str] = None):
    """Install the TP context for one trace (wrap the shard_map body)."""
    prev = _TP.ctx
    if ways > 1 or batch_axis is not None:
        _TP.ctx = TPContext(axis=axis, ways=ways, batch_axis=batch_axis)
    else:
        _TP.ctx = None
    try:
        yield
    finally:
        _TP.ctx = prev


def tp_ctx() -> Optional[TPContext]:
    return _TP.ctx


# ---------------------------------------------------------------------------
# shard_map across jax versions (mirrors models/moe.py)
# ---------------------------------------------------------------------------

def shard_map_compat(fn, mesh: Mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def mesh_axis_size(mesh: Optional[Mesh], axis: str) -> int:
    if mesh is None:
        return 1
    return mesh.shape.get(axis, 1)


# ---------------------------------------------------------------------------
# per-shard model config
# ---------------------------------------------------------------------------

def validate_tp_config(cfg: ModelConfig, ways: int) -> None:
    """Raise listing every dimension the model axis cannot divide.

    The serving TP path is strict on purpose: degrading a single
    projection to replication would break the psum placement the
    row-parallel call sites assume (see module docstring).
    """
    if ways <= 1:
        return
    problems: List[str] = []
    if cfg.n_heads % ways:
        problems.append(f"n_heads={cfg.n_heads} % model={ways}")
    if cfg.n_kv_heads % ways:
        problems.append(f"n_kv_heads={cfg.n_kv_heads} % model={ways}")
    if cfg.d_ff and cfg.d_ff % (2 * ways):
        # row-parallel w_down is nibble-PACKED along K: each shard's K
        # slice must cover whole bytes, hence the extra factor of 2
        problems.append(f"d_ff={cfg.d_ff} % 2*model={2 * ways}")
    if cfg.moe_d_ff and cfg.moe_d_ff % (2 * ways):
        problems.append(f"moe_d_ff={cfg.moe_d_ff} % 2*model={2 * ways}")
    if not cfg.tie_embeddings and cfg.vocab % ways:
        problems.append(f"vocab={cfg.vocab} % model={ways}")
    if problems:
        raise ValueError(
            f"config {cfg.name!r} cannot shard {ways}-way on the model "
            f"axis: " + ", ".join(problems))


def shard_model_config(cfg: ModelConfig, ways: int) -> ModelConfig:
    """The per-shard config the shard_map body runs: head counts divided
    by the model ways, head_dim pinned so ``cfg.hd`` stays the global
    value. Everything else (d_model, vocab, capacity factors, ...) is
    untouched — runtime shapes flow from the (sharded) params."""
    if ways <= 1:
        return cfg
    validate_tp_config(cfg, ways)
    return cfg.replace(n_heads=cfg.n_heads // ways,
                       n_kv_heads=cfg.n_kv_heads // ways,
                       head_dim=cfg.hd)


# ---------------------------------------------------------------------------
# partition-spec trees
# ---------------------------------------------------------------------------

# projection leaves by Megatron role (keys of the param tree; the same
# name set core/qlinear.quantize_model_params rewrites)
_COL_KEYS = frozenset({"wq", "wk", "wv", "w_gate", "w_up", "w_fc",
                       "lm_head", "w_shared_gate", "w_shared_up"})
_ROW_KEYS = frozenset({"wo", "w_down", "w_proj", "w_shared_down"})
_COL_BIAS_KEYS = frozenset({"bq", "bk", "bv", "b_fc"})


def _last_dim(ndim: int, axis: str) -> P:
    return P(*([None] * (ndim - 1) + [axis]))


def _dim(ndim: int, which: int, axis: str) -> P:
    spec: List[Optional[str]] = [None] * ndim
    spec[which] = axis
    return P(*spec)


def _sl_pspecs(sl, kind: str, axis: str):
    """Partition-spec 'SparqleLinear' mirroring one quantized leaf.

    col: weight sharded on output channels (q/scale/zero last dim).
    row: weight sharded on the (packed) K dim; scales replicated (they
    are per-output-channel); the column-importance mask follows K.
    """
    from repro.core.qlinear import SparqleLinear
    from repro.core.quantize import QuantizedTensor
    q, scale = sl.w.q, sl.w.scale
    if kind == "col":
        qs = _last_dim(q.ndim, axis)
        ss = _last_dim(scale.ndim, axis)
        ms = None if sl.col_mask is None else P()
    else:
        qs = _dim(q.ndim, q.ndim - 2, axis)
        ss = P()
        ms = None if sl.col_mask is None else _last_dim(sl.col_mask.ndim,
                                                        axis)
    lh = None if sl.l is None else P()
    return SparqleLinear(
        w=QuantizedTensor(q=qs, scale=ss, zero=ss, bits=sl.w.bits),
        col_mask=ms, l=lh, h=None if sl.h is None else P(),
        mode=sl.mode, packed=sl.packed, wire_format=sl.wire_format)


def param_pspecs(params: Dict[str, Any], axis: str = "model") -> Any:
    """PartitionSpec tree for a (quantized) serving param tree.

    Projections are partitioned on ``axis`` per the column/row table
    above; float leaves (norms, embedding table, router, row-parallel
    biases) replicate. Works for float param trees too (the same names
    shard their float leaves), though only int-accumulating quantized
    modes carry the bit-exactness guarantee.
    """
    from repro.core.qlinear import SparqleLinear

    def leaf_spec(key: str, v):
        if isinstance(v, SparqleLinear):
            if key in _COL_KEYS:
                return _sl_pspecs(v, "col", axis)
            if key in _ROW_KEYS:
                return _sl_pspecs(v, "row", axis)
            return jax.tree_util.tree_map(lambda x: P(), v)
        if v is None:
            return None
        if key in _COL_KEYS:
            return _last_dim(v.ndim, axis)
        if key in _ROW_KEYS:
            return _dim(v.ndim, v.ndim - 2, axis)
        if key in _COL_BIAS_KEYS:
            return _last_dim(v.ndim, axis)
        return P()

    def walk(tree):
        out = {}
        for k, v in tree.items():
            out[k] = walk(v) if isinstance(v, dict) else leaf_spec(k, v)
        return out

    return walk(params)


def pool_pspecs(cfg: ModelConfig, pool_cfg, mesh: Mesh) -> Any:
    """PartitionSpec tree for the paged pool state, straight from the
    logical-axis rule table: ``pages`` -> "data", ``kv_heads`` -> "model"
    (see ``serving/kv_pool.pool_schema``)."""
    from repro.distributed.sharding import spec_for
    from repro.models.schema import ParamSpec
    from repro.serving.kv_pool import pool_schema
    return jax.tree_util.tree_map(
        lambda s: spec_for(s.axes, s.shape, mesh),
        pool_schema(cfg, pool_cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec))


def device_put_tree(tree: Any, pspecs: Any, mesh: Mesh) -> Any:
    """Place every leaf per its PartitionSpec tree (same structure;
    ``None`` leaves pair with ``None`` specs and are skipped)."""
    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, tree, pspecs)
