"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter/activation dimension carries a *logical* axis name; a rule
table maps logical names to mesh axes. ``spec_for`` checks divisibility of
the actual dim size against the mesh axis size and degrades to replication
when it doesn't divide (e.g. hubert's vocab=504 on a 16-way model axis),
so one rule table serves all 13 architectures on the fixed production mesh.

Logical axes used across the repo:

  batch      — global batch            -> ("pod", "data")
  seq        — sequence                -> None (sequence parallelism is a
                                           perf-iteration knob, off by default)
  embed      — d_model                 -> None for activations; "data" (FSDP)
                                           for large params
  heads      — attention q heads      -> "model"
  kv_heads   — attention kv heads     -> "model"
  mlp        — d_ff                   -> "model"
  vocab      — vocabulary             -> "model"
  experts    — MoE experts            -> "model"
  capacity   — MoE capacity slots     -> "data"
  layers     — stacked scan dim       -> None
  fsdp       — explicit FSDP dim      -> "data"
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": ("data", "model"),     # KV-cache seq: claims whatever axes the
                                     # batch dim left free (long-context /
                                     # small-KV-head decode sharding)
    "embed": ("data", "pod"),        # params: FSDP dim; activations: batch
                                     # claims these axes first -> replicated
    "heads": ("model",),
    "heads_flat": ("model",),        # flattened H*hd projection dim
    "kv_heads": ("model",),
    "pages": ("data",),              # paged KV pool slab: each data shard
                                     # owns a slab of pages (serving TP)
    "qk_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "capacity": ("data",),
    "layers": (),
    "fsdp": ("data",),
    "conv": (),
    "state": (),
    None: (),
}


class _MeshContext(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = _MeshContext()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Optional[Dict] = None):
    """Install an ambient mesh + rules; model code constrains against it."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    if rules:
        merged = dict(DEFAULT_RULES)
        merged.update(rules)
        _CTX.rules = merged
    try:
        with mesh:
            yield mesh
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


@contextlib.contextmanager
def no_mesh():
    """Suspend the ambient mesh (constrain becomes a no-op).

    Used while tracing a ``shard_map`` body: inside manual-sharding
    regions ``with_sharding_constraint`` against the outer mesh is
    invalid, and the distributed MoE dispatch must take its local
    (single-shard) path — the TP context (``distributed/tp.py``) carries
    the collective placement instead.
    """
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh, _CTX.rules = None, dict(DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axes_for(logical: Optional[str], dim: int, mesh: Mesh,
              rules: Dict, used: set) -> Optional[Tuple[str, ...]]:
    """Mesh axes for one dim, or None if not divisible / unmapped.

    Axes already claimed by an earlier dim are filtered out (not fatal), so
    e.g. a KV cache rule ("data", "model") degrades to ("model",) when the
    batch dim already took "data". Divisibility falls back over prefixes.
    """
    names = rules.get(logical, ())
    names = tuple(n for n in names if n in mesh.shape and n not in used)
    for cut in range(len(names), 0, -1):
        sub = names[:cut]
        t = 1
        for n in sub:
            t *= mesh.shape[n]
        if dim % t == 0 and t > 1:
            return sub
    return None


def spec_for(logical_axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Optional[Mesh] = None,
             rules: Optional[Dict] = None) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names.

    Divisibility-checked per dim; mesh axes are never used twice (first dim
    that claims an axis wins — matches rule-table priority order).
    """
    mesh = mesh or active_mesh()
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    used: set = set()
    entries = []
    for logical, dim in zip(logical_axes, shape):
        axes = _axes_for(logical, dim, mesh, rules, used)
        if axes:
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (no-op without one)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int],
                   mesh: Optional[Mesh] = None) -> NamedSharding:
    mesh = mesh or active_mesh()
    assert mesh is not None, "named_sharding requires a mesh"
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh))


# ---------------------------------------------------------------------------
# sharding profiles (§Perf iterations)
# ---------------------------------------------------------------------------

def profile_rules(profile: str, cfg, kind: str, mesh: Mesh,
                  global_batch: int = 0) -> Dict:
    """Rule overrides per performance profile.

    ``baseline`` — the paper-faithful first build: FSDP everywhere (params
    shard their non-model dim over data/pod), which is what EXPERIMENTS.md
    §Roofline baselines record.

    ``tuned`` — §Perf iteration 1: drop FSDP (replicate params over the
    data axes) whenever the per-device resident state fits comfortably,
    eliminating the dominant per-layer/per-microbatch parameter
    all-gathers. Training keeps f32 master + 2 bf16 moments resident
    (8 B/param over the model axis); serving keeps int8 weights + scales
    (~1.2 B/param).
    """
    if profile == "baseline":
        return {}
    data_ways = 1
    for a in ("pod", "data"):
        data_ways *= mesh.shape.get(a, 1)
    # degenerate-batch decode (e.g. long_500k, B=1): per-step work is one
    # token — replicating weights inflates the per-device stream for no
    # collective win; keep them FSDP-sharded.
    if kind == "decode" and 0 < global_batch < data_ways:
        return {}
    from repro.models.schema import param_count
    from repro.models.schema_builder import build_schema
    n = param_count(build_schema(cfg))
    model_ways = mesh.shape.get("model", 1)
    if kind == "train":
        resident = n * 8.0 / model_ways
    else:
        resident = n * 1.2 / model_ways
    if resident < 8e9:
        return {"embed": ()}          # no FSDP: params replicate over data
    return {}
