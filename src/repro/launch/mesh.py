"""Production mesh construction (deliverable e).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. The single-pod mesh is
16x16 = 256 chips over ("data", "model"); the multi-pod mesh adds an outer
"pod" axis: 2 pods x 256 = 512 chips. The pod axis is the DCN-connected
outer data-parallel axis (per-pod replica groups; gradients cross pods once
per step), composing data parallelism over ICI within a pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many (CPU) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))
