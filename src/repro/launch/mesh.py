"""Production mesh construction (deliverable e).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. The single-pod mesh is
16x16 = 256 chips over ("data", "model"); the multi-pod mesh adds an outer
"pod" axis: 2 pods x 256 = 512 chips. The pod axis is the DCN-connected
outer data-parallel axis (per-pod replica groups; gradients cross pods once
per step), composing data parallelism over ICI within a pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many (CPU) devices exist — used by tests
    and the ``--mesh`` serving path."""
    need = data * model
    have = len(jax.devices())
    if need > have:
        raise RuntimeError(
            f"make_smoke_mesh(data={data}, model={model}) needs {need} "
            f"devices but jax sees {have}. On CPU, emulate host devices "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} (it must be set in the environment BEFORE jax "
            f"initializes — the multi-device CI lane and "
            f"tests/conftest.py's `mesh` fixture rely on this).")
    return jax.make_mesh((data, model), ("data", "model"))
