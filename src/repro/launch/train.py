"""End-to-end training driver with checkpoint/restart and fault injection.

Runs any registered architecture (full or --smoke reduced config) on the
available devices with the full production substrate: synthetic packed data
pipeline, microbatched AdamW train step, async checkpointing, restartable
step loop with straggler deadline, optional injected faults (to demo/test
recovery), and optional int8 cross-pod gradient compression.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --ckpt-dir /tmp/ck
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --ckpt-dir /tmp/ck --resume auto --inject-fail 17
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.fault import FaultInjector, RestartableLoop
from repro.distributed.sharding import mesh_context
from repro.checkpoint import store
from repro.launch import steps as S
from repro.launch.mesh import make_smoke_mesh
from repro.models.registry import get_config
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.optim.adamw import OptConfig, init_opt_state


def build_state(cfg: ModelConfig, ocfg: OptConfig, seed: int) -> S.TrainState:
    params = init_params(build_schema(cfg), jax.random.PRNGKey(seed))
    return S.TrainState(params=params, opt=init_opt_state(params, ocfg))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--inject-fail", type=int, default=None,
                    help="inject a step failure at this step (recovery demo)")
    ap.add_argument("--deadline-s", type=float, default=1e9)
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family in ("encoder", "vlm"):
        raise SystemExit(f"{args.arch}: use examples/ for non-LM training "
                         "drivers (frontend stubs needed)")
    ocfg = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                     total_steps=args.steps)
    knobs = S.TrainKnobs(microbatch=args.microbatch,
                         ce_chunk=min(512, args.seq),
                         compress_pod_grads=args.compress_pod_grads)

    mesh = make_smoke_mesh(data=args.data_axis, model=args.model_axis)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    with mesh_context(mesh):
        step_fn = jax.jit(S.make_train_step(cfg, ocfg, knobs),
                          donate_argnums=0)
        state = build_state(cfg, ocfg, args.seed)

        start = 0
        if args.resume == "auto":
            latest = store.latest_step(args.ckpt_dir)
            if latest is not None:
                state = store.restore(args.ckpt_dir, latest, state)
                start = latest
                print(f"resumed from step {start}")

        hist = []
        t0 = time.time()

        def make_batch(step):
            return {k: jnp.asarray(v)
                    for k, v in data.batch_at(step).items()}

        def logged_step(st, batch):
            st, m = step_fn(st, batch)
            hist.append(float(m["loss"]))
            n = len(hist)
            if n % args.log_every == 0:
                dt = (time.time() - t0) / n
                print(f"step {start + n:5d} loss {hist[-1]:.4f} "
                      f"({dt*1e3:.0f} ms/step)", flush=True)
            return st, m

        injector = None
        if args.inject_fail is not None:
            injector = FaultInjector(plan={args.inject_fail: "fail"})

        loop = RestartableLoop(
            logged_step, make_batch, args.ckpt_dir,
            ckpt_every=args.ckpt_every, injector=injector,
            deadline_s=args.deadline_s, async_ckpt=args.async_ckpt)
        state, metrics = loop.run(state, start, args.steps)

        print(f"done: {loop.report}")
        print(f"final loss {hist[-1]:.4f} (first {hist[0]:.4f})")


if __name__ == "__main__":
    main()
