"""Jittable step functions: train_step / serve_prefill / serve_decode.

These are the units the launchers run and the dry-run lowers. Everything
scale-critical lives here:

  * microbatched gradient accumulation (``lax.scan`` over microbatches) —
    bounds activation memory and MoE dispatch buffers;
  * chunked cross-entropy — the (tokens, vocab) logits tensor is never
    materialized for the whole batch (deepseek's 129k / gemma3's 262k
    vocab would be 100s of GB at train_4k); the head+CE run per sequence
    chunk inside a scan, recomputed in backward via remat;
  * remat (nothing saveable) over the layer scan;
  * optional int8 error-feedback compression of the cross-pod gradient
    all-reduce (``TrainKnobs.compress_pod_grads``);
  * SPARQLe-quantized serving steps (the paper path) with KV4 caches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.distributed.sharding import spec_for
from repro.models import model as M
from repro.models.qschema import (build_quantized_schema, tree_abstract,
                                  tree_shardings)
from repro.models.registry import cache_schema
from repro.models.schema import ParamSpec, Schema
from repro.models.schema_builder import build_schema
from repro.optim.adamw import OptConfig, OptState, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainKnobs:
    microbatch: int = 0          # 0 = no accumulation (whole batch at once)
    remat: bool = True
    ce_chunk: int = 512          # sequence chunk for the chunked CE
    mtp_weight: float = 0.3
    aux_weight: float = 0.01
    compress_pod_grads: bool = False


class TrainState(NamedTuple):
    params: Any
    opt: OptState


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token cross-entropy, f32-stable. logits (..., V), targets (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_ce(cfg: ModelConfig, params, hidden: jax.Array,
               targets: jax.Array, chunk: int) -> jax.Array:
    """CE over the vocab head without materializing (B, S, V).

    Scans over sequence chunks; the head matmul + softmax of each chunk is
    recomputed in the backward pass (jax.checkpoint), so peak logits
    memory is (B, chunk, V).
    """
    b, s, d = hidden.shape
    assert targets.shape == (b, s), (hidden.shape, targets.shape)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, [(0, 0), (0, pad), (0, 0)])
        targets = jnp.pad(targets, [(0, 0), (0, pad)], constant_values=-1)
    sp = s + pad
    nc = sp // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(h, t):
        logits = M.head_logits(cfg, params, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(t, 0)[..., None], axis=-1)[..., 0]
        valid = (t >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    def body(carry, xs):
        tot, cnt = carry
        h, t = xs
        l, n = one(h, t)
        return (tot + l, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, tc))
    return tot / jnp.maximum(cnt, 1.0)


def cast_params_for_compute(cfg: ModelConfig, params):
    """Cast float params to the compute dtype ONCE, before any use.

    Critical under FSDP: the per-layer all-gather then moves bf16 instead
    of the f32 master copy (half the gather bytes and half the gathered
    temp footprint). jax.grad transposes the cast back to f32 grads.

    MoE expert subtrees are excluded: a convert feeding the shard_map
    dispatch trips an XLA CPU-backend CHECK failure ("Invalid binary
    instruction opcode copy") in the transpose; expert weights therefore
    gather in f32 on this backend (2x expert-gather bytes — noted in
    EXPERIMENTS.md §Perf as recoverable on the TPU backend).
    """
    dt = cfg.cdtype

    def walk(tree, in_moe=False):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v, in_moe or k == "moe")
            elif (not in_moe and hasattr(v, "dtype")
                  and v.dtype == jnp.float32):
                out[k] = v.astype(dt)
            else:
                out[k] = v
        return out

    return walk(params)


def loss_fn(cfg: ModelConfig, knobs: TrainKnobs, params,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    params = cast_params_for_compute(cfg, params)
    hidden, aux = M.forward_hidden(cfg, params, batch, remat=knobs.remat,
                                   with_aux=True)
    targets = batch["targets"]
    if cfg.family == "vlm":      # targets cover only the text positions
        hidden_t = hidden[:, cfg.n_prefix:cfg.n_prefix + targets.shape[1]]
    else:
        hidden_t = hidden
    ce = chunked_ce(cfg, params, hidden_t, targets, knobs.ce_chunk)
    loss = ce + knobs.aux_weight * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth:
        mtp_lg = M.mtp_logits(cfg, params, hidden, batch)
        # MTP position i predicts tokens[i+2] == targets[i+1]
        mtp_ce = _xent(mtp_lg, targets[:, 1:])
        loss = loss + knobs.mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return loss, metrics


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, ocfg: OptConfig,
                    knobs: TrainKnobs = TrainKnobs()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, knobs, p, b), has_aux=True)

    def accum_grads(params, batch):
        mb = knobs.microbatch
        b = batch["targets"].shape[0]
        if not mb or mb >= b:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        assert b % mb == 0, (b, mb)
        n = b // mb
        split = jax.tree_util.tree_map(
            lambda x: x.reshape(n, mb, *x.shape[1:]), batch)

        def body(carry, ubatch):
            gsum, lsum = carry
            (loss, _), grads = grad_fn(params, ubatch)
            gsum = jax.tree_util.tree_map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), split)
        grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
        return lsum / n, {"ce": lsum / n}, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, metrics, grads = accum_grads(state.params, batch)
        if knobs.compress_pod_grads:
            # int8 EF compression of the cross-pod gradient reduction.
            # Inside pjit the pod all-reduce is implicit; quantize-
            # dequantize here shrinks the tensors XLA moves across the
            # DCN-mapped axis (error feedback folded into this step).
            from repro.optim.adamw import compress_grads, decompress_grads
            q, _err = compress_grads(grads)
            grads = decompress_grads(q)
        new_params, opt, om = adamw_update(state.params, grads, state.opt,
                                           ocfg)
        metrics = dict(metrics, loss=loss, **om)
        return TrainState(new_params, opt), metrics

    return train_step


# ---------------------------------------------------------------------------
# serving steps (SPARQLe path)
# ---------------------------------------------------------------------------

def make_serve_prefill(cfg: ModelConfig, max_len: int):
    def serve_prefill(params, batch):
        logits, cache = M.prefill(cfg, params, batch, max_len=max_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_prefill


def make_serve_decode(cfg: ModelConfig):
    def serve_decode(params, cache, token, pos):
        logits, cache = M.decode_step(cfg, params, cache, token, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_decode


# ---------------------------------------------------------------------------
# continuous-batching engine steps (serving/engine.py) — paged KV pool
# ---------------------------------------------------------------------------

def _mesh_layout(cfg: ModelConfig, mesh: Mesh):
    """(local cfg, model_ways, data axis name or None) for a step body."""
    from repro.distributed.tp import mesh_axis_size, shard_model_config
    mways = mesh_axis_size(mesh, "model")
    daxis = "data" if mesh_axis_size(mesh, "data") > 1 else None
    return shard_model_config(cfg, mways), mways, daxis


def with_trace_annotation(name: str, fn):
    """Wrap an already-compiled step so each CALL runs inside
    ``jax.profiler.TraceAnnotation(name)`` — the annotation brackets the
    host-side dispatch, it is never traced into the computation, so the
    wrapped fn's jaxpr/HLO and donation behavior are untouched. No-op
    passthrough if the profiler API is unavailable."""
    try:
        annotation = jax.profiler.TraceAnnotation
    except AttributeError:
        return fn

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with annotation(name):
            return fn(*args, **kwargs)
    return wrapped


def abstract_like(tree: Any) -> Any:
    """Map a tree of live arrays to ``ShapeDtypeStruct`` avals.

    The attribution layer (``obs/attribution.py``) lowers each serving
    step a second time to inspect its optimized HLO; doing that against
    abstract avals — rather than the live arguments — means buffers
    marked for donation in the real jitted step are never at risk, and
    no device transfer happens. Shardings are preserved when the leaf
    carries one (sharded engines lower to the same SPMD program the
    runtime executes).
    """
    def _leaf(x: Any) -> jax.ShapeDtypeStruct:
        sharding = getattr(x, "sharding", None)
        try:
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sharding)
        except TypeError:
            return jax.ShapeDtypeStruct(x.shape, x.dtype)
    return jax.tree_util.tree_map(_leaf, tree)


def make_engine_prefill_chunk(cfg: ModelConfig, *,
                              mesh: Optional[Mesh] = None,
                              param_specs=None, pool_specs=None):
    """Chunked prefill of ONE sequence into the paged pool.

    (params, pool, tokens (1, C), start, valid, block_table (1, Pmax))
    -> (logits (1, V) at the last valid position, new pool, telemetry) —
    telemetry carries the chunk's mean MSB4 sparsity plus per-layer
    measured packed-wire vs dense activation bytes (see
    ``models.model.prefill_chunk_paged``). Shape-static in C and Pmax,
    so the engine compiles this once.

    With a ``mesh``, the same body runs inside shard_map on a per-shard
    config (weights model-partitioned, pool pages data-sharded; see
    docs/sharding.md) and ``block_table`` widens to (D, Pmax) — one row
    per data shard, the owning shard's row holding the sequence's
    shard-local pages, every other row all-null. Non-owning shards
    compute into their null page; the owner's logits/telemetry are
    selected with an exact where-masked psum over the data axis.
    """
    if mesh is None:
        def prefill_chunk(params, pool, tokens, start, valid, block_table):
            return M.prefill_chunk_paged(cfg, params, pool, tokens, start,
                                         valid, block_table)

        return prefill_chunk

    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import no_mesh
    from repro.distributed.tp import shard_map_compat, tp_scope
    lcfg, mways, daxis = _mesh_layout(cfg, mesh)

    def body(params, pool, tokens, start, valid, table):
        # prefill is replicated over data (the chunk's batch dim is 1):
        # no batch_axis in the TP context, so MoE routes the local chunk
        with no_mesh(), tp_scope("model", mways, batch_axis=None):
            logits, pool, tel = M.prefill_chunk_paged(
                lcfg, params, pool, tokens, start, valid, table)
        if daxis is not None:
            # exactly one data shard holds the sequence's pages (nonzero
            # block-table row); a where-masked psum selects its values
            # bit-exactly (a sum with a single nonzero term)
            mine = jnp.any(table != 0)
            sel = lambda t: jax.lax.psum(  # noqa: E731
                jnp.where(mine, t, jnp.zeros_like(t)), daxis)
            logits = sel(logits)
            tel = {k: sel(v) for k, v in tel.items()}
        return logits, pool, tel

    tel_specs = {"sparsity": P(), "layer_sparsity": P(None),
                 "layer_wire_bytes": P(None), "layer_dense_bytes": P(None)}
    return shard_map_compat(
        body, mesh,
        in_specs=(param_specs, pool_specs, P(), P(), P(), P(daxis, None)),
        out_specs=(P(), pool_specs, tel_specs))


def make_engine_decode(cfg: ModelConfig, *, msb_skip: bool = False,
                       with_telemetry: bool = True, kv2: bool = False,
                       mesh: Optional[Mesh] = None,
                       param_specs=None, pool_specs=None):
    """One continuous-batching decode step over every decode slot.

    (params, pool, token (B,), pos (B,), block_tables (B, Pmax))
    -> (logits (B, V), new pool, telemetry) — telemetry carries per-slot
    hidden MSB4 sparsity (B,) plus per-layer (L, B) measured packed-wire
    vs dense activation bytes (see ``models.model.decode_step_paged``).
    Raw logits come back (not argmax'd): sampling policy is per-request
    and lives host-side in the engine.

    ``msb_skip=True`` builds the LSB4-only *draft* step of the
    self-speculative engine: every sparqle projection is traced with the
    sparse MSB pass statically elided (1 compute round instead of
    1 + (1 - s); paper §3.3). ``with_telemetry=False`` additionally drops
    the wire accounting from the traced program (telemetry comes back
    empty) — the draft runs γ times per emitted batch, so it stays lean.

    ``kv2=True`` builds the precision-ladder decode step instead: the
    returned function takes an extra ``tier_tables`` (B, Pmax) argument
    after ``block_tables`` and reads each page from the slab its tier id
    names (``models.model.decode_step_paged`` with ``tier_tables``; the
    pool state must carry the KV2 slab, i.e. ``PoolConfig.kv2_pages >
    0``). Unsharded engines only — the ladder's host bookkeeping is
    single-pool.

    With a ``mesh``, the step runs inside shard_map: decode slots shard
    over the "data" axis (block tables carry the slot's data shard's
    local page ids), KV heads and weights over "model". Logits come back
    with the vocab shards gathered, so the host-side sampling loop is
    unchanged.
    """
    if kv2 and mesh is not None:
        raise NotImplementedError(
            "the KV2 precision ladder is unsharded-only (kv2=True with a "
            "mesh is not wired up; see docs/serving.md)")
    if mesh is None:
        if kv2:
            def engine_decode_kv2(params, pool, token, pos, block_tables,
                                  tier_tables):
                return M.decode_step_paged(cfg, params, pool, token, pos,
                                           block_tables,
                                           tier_tables=tier_tables,
                                           msb_skip=msb_skip,
                                           with_telemetry=with_telemetry)

            return engine_decode_kv2

        def engine_decode(params, pool, token, pos, block_tables):
            return M.decode_step_paged(cfg, params, pool, token, pos,
                                       block_tables, msb_skip=msb_skip,
                                       with_telemetry=with_telemetry)

        return engine_decode

    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import no_mesh
    from repro.distributed.tp import shard_map_compat, tp_scope
    lcfg, mways, daxis = _mesh_layout(cfg, mesh)

    def body(params, pool, token, pos, tables):
        with no_mesh(), tp_scope("model", mways, batch_axis=daxis):
            return M.decode_step_paged(lcfg, params, pool, token, pos,
                                       tables, msb_skip=msb_skip,
                                       with_telemetry=with_telemetry)

    B, LB = P(daxis), P(None, daxis)
    tel_specs = ({"sparsity": B, "layer_sparsity": LB,
                  "layer_wire_bytes": LB, "layer_dense_bytes": LB}
                 if with_telemetry else {})
    return shard_map_compat(
        body, mesh,
        in_specs=(param_specs, pool_specs, B, B, P(daxis, None)),
        out_specs=(P(daxis, None), pool_specs, tel_specs))


def make_engine_verify_window(cfg: ModelConfig, *,
                              mesh: Optional[Mesh] = None,
                              param_specs=None, pool_specs=None):
    """Full-precision batched verification of a γ-token draft window.

    (params, pool, tokens (B, T), pos (B,), block_tables (B, Pmax))
    -> (logits (B, T, V), new pool, telemetry) — one step scores every
    window position of every decode slot at once and overwrites the
    draft's approximate K/V with full-precision values (see
    ``models.model.verify_window_paged``). Shape-static in T = γ + 1, so
    the speculative engine compiles exactly one extra XLA program per γ.

    With a ``mesh``, sharded exactly like :func:`make_engine_decode`
    (the window axis T stays per-shard-complete).
    """
    if mesh is None:
        def engine_verify(params, pool, tokens, pos, block_tables):
            return M.verify_window_paged(cfg, params, pool, tokens, pos,
                                         block_tables)

        return engine_verify

    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import no_mesh
    from repro.distributed.tp import shard_map_compat, tp_scope
    lcfg, mways, daxis = _mesh_layout(cfg, mesh)

    def body(params, pool, tokens, pos, tables):
        with no_mesh(), tp_scope("model", mways, batch_axis=daxis):
            return M.verify_window_paged(lcfg, params, pool, tokens, pos,
                                         tables)

    B, LB = P(daxis), P(None, daxis)
    tel_specs = {"sparsity": B, "layer_sparsity": LB,
                 "layer_wire_bytes": LB, "layer_dense_bytes": LB}
    return shard_map_compat(
        body, mesh,
        in_specs=(param_specs, pool_specs, P(daxis, None), B,
                  P(daxis, None)),
        out_specs=(P(daxis, None, None), pool_specs, tel_specs))


def pool_abstract_and_shardings(cfg: ModelConfig, n_pages: int,
                                page_size: int, mesh: Mesh):
    """Dry-run plumbing for the serving pool (mirrors the cache helper)."""
    from repro.serving.kv_pool import PoolConfig, pool_schema
    ps = pool_schema(cfg, PoolConfig(n_pages=n_pages, page_size=page_size))
    return tree_abstract(ps), tree_shardings(ps, mesh)


# ---------------------------------------------------------------------------
# abstract state + shardings (dry-run / launcher plumbing)
# ---------------------------------------------------------------------------

def _spec_tree_opt(schema: Schema) -> Schema:
    """ParamSpec tree for AdamW moments mirroring the param schema."""
    def conv(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, jnp.bfloat16, init="zeros")
    return jax.tree_util.tree_map(
        conv, schema, is_leaf=lambda x: isinstance(x, ParamSpec))


def train_state_schema(cfg: ModelConfig) -> Any:
    """ParamSpec pytree of the full TrainState (params f32 + moments)."""
    pschema = build_schema(cfg)
    step = ParamSpec((), (), jnp.int32, init="zeros")
    return TrainState(
        params=pschema,
        opt=OptState(step=step, mu=_spec_tree_opt(pschema),
                     nu=_spec_tree_opt(pschema)))


def serve_param_schema(cfg: ModelConfig, mode: str = "sparqle") -> Any:
    """SPARQLe-quantized param schema (the served form)."""
    return build_quantized_schema(build_schema(cfg), w_bits=cfg.w_bits,
                                  mode=mode)


def batch_shardings(batch_abstract: Dict[str, jax.ShapeDtypeStruct],
                    mesh: Mesh) -> Dict[str, NamedSharding]:
    out = {}
    for k, v in batch_abstract.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, spec_for(axes, v.shape, mesh))
    return out


def abstract_and_shardings(schema_tree: Any, mesh: Mesh):
    return tree_abstract(schema_tree), tree_shardings(schema_tree, mesh)


def cache_abstract_and_shardings(cfg: ModelConfig, batch: int, max_len: int,
                                 mesh: Mesh):
    cs = cache_schema(cfg, batch, max_len)
    return tree_abstract(cs), tree_shardings(cs, mesh)
