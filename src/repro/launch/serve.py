"""Serving driver on the SPARQLe quantized path.

Default: the continuous-batching engine (`repro.serving`) — requests are
admitted FCFS under a token budget into a paged packed-KV4 cache pool,
prefill is chunked, decode slots are backfilled every step, and decode
attention streams the pool in wire format through the paged Pallas
kernel. Reports per-request TTFT/TPOT, generation throughput, achieved
MSB4 sub-precision sparsity, and the cost model's prediction at that
sparsity (paper §5.1).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --prompt-len 64 --gen 16 --batch 4

``--legacy`` runs the original fixed-batch path (one monolithic cache,
single prefill + lockstep Python decode loop) for comparison; paged-vs-
legacy token equivalence is covered by tests/test_serving.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core.costmodel import (HardwareConfig, LMShape, evaluate_model)
from repro.core.qlinear import quantize_model_params
from repro.core.quantize import quantize_activations
from repro.core.sparqle import subprecision_sparsity
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.sharding import mesh_context
from repro.launch import steps as S
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.models.registry import get_config
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema


def _legacy_serve(cfg, qparams, batch, plen, args) -> None:
    max_len = plen + args.gen
    prefill = jax.jit(S.make_serve_prefill(cfg, max_len))
    decode = jax.jit(S.make_serve_decode(cfg))

    t0 = time.time()
    tok, cache = prefill(qparams, batch)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch,), plen + i, jnp.int32)
        tok, cache = decode(qparams, cache, tok, pos)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = (time.time() - t0) / max(1, args.gen - 1)

    gen = jnp.stack(out, 1)
    print(f"generated {gen.shape} tokens; "
          f"prefill {t_prefill*1e3:.0f} ms, "
          f"{t_decode*1e3:.1f} ms/token (CPU interpret timings)")


def _engine_serve(cfg, qparams, prompts, args, serve_mesh=None):
    from repro.serving import (Engine, PoolConfig, SamplingParams,
                               SchedulerConfig, SpecConfig,
                               SpeculativeEngine)
    gamma = getattr(args, "spec_gamma", 0)
    data_ways = 1
    if serve_mesh is not None:
        data_ways = serve_mesh.shape.get("data", 1)
    pages_per_seq = -(-(args.prompt_len + args.gen + gamma)
                      // args.page_size)
    n_slots = min(args.batch, args.decode_slots)
    n_slots += (-n_slots) % data_ways            # slots split over data
    # a request's pages live in ONE data shard, so the default pool must
    # give every shard room for its share of the batch (ceil), not an
    # even split of the global worst case
    batch_per_shard = -(-args.batch // data_ways)
    n_pages = args.n_pages or (
        data_ways * (1 + pages_per_seq * batch_per_shard))
    n_pages += (-n_pages) % data_ways            # pages split over data
    from repro.obs.slo import parse_slo_list
    slos = [slo for s in getattr(args, "slo", None) or []
            for slo in parse_slo_list(s)]
    kw = dict(
        pool_config=PoolConfig(n_pages=n_pages, page_size=args.page_size),
        sched_config=SchedulerConfig(
            max_decode_batch=n_slots,
            token_budget=args.token_budget,
            prefill_chunk=args.prefill_chunk,
            max_pages_per_seq=pages_per_seq),
        mesh=serve_mesh, slos=slos)
    if gamma > 0:
        eng = SpeculativeEngine(cfg, qparams, spec=SpecConfig(gamma=gamma),
                                **kw)
    else:
        eng = Engine(cfg, qparams, **kw)
    if getattr(args, "attribute", False):
        attr = eng.attribute_steps()
        for phase, c in sorted(attr.summary().items()):
            print(f"attributed {phase}: {c['flops']/1e6:.1f} MFLOP/step, "
                  f"{c['hbm_bytes']/1e6:.1f} MB HBM/step, "
                  f"{c['coll_bytes_total']/1e3:.1f} kB collectives "
                  f"(compiled in {c['compile_seconds']:.2f} s)")
    if serve_mesh is not None:
        print(f"serving on mesh {dict(serve_mesh.shape)} "
              f"({serve_mesh.size} devices): decode slots/pages sharded "
              f"over 'data', weights+KV heads over 'model'")
    t0 = time.time()
    handles = [eng.submit(np.asarray(p).tolist(),
                          SamplingParams(max_new_tokens=args.gen))
               for p in prompts]
    eng.run()
    wall = time.time() - t0

    stats = [h.stats() for h in handles]
    n_tok = sum(s["n_generated"] for s in stats)
    ttft = [s["ttft_s"] for s in stats]
    tpot = [s["tpot_s"] for s in stats if np.isfinite(s["tpot_s"])]
    spars = [s["act_sparsity"] for s in stats]
    print(f"engine: {len(handles)} requests, {n_tok} tokens in "
          f"{wall:.2f} s ({n_tok / wall:.1f} tok/s, "
          f"{eng.steps} steps; CPU interpret timings)")
    print(f"  TTFT  mean {np.mean(ttft)*1e3:.0f} ms  "
          f"p95 {np.percentile(ttft, 95)*1e3:.0f} ms")
    if tpot:
        print(f"  TPOT  mean {np.mean(tpot)*1e3:.1f} ms/token")
    print(f"  decode-time MSB4 sparsity mean {np.mean(spars)*100:.1f}%")
    agg = eng.aggregate_stats()
    if "wire_compression_pct" in agg:
        print(f"  measured wire format: {agg['wire_compression_pct']:.1f}% "
              f"activation bytes saved vs dense int8 "
              f"({agg['wire_bytes_total']/1e3:.1f} kB on the wire)")
    if "spec_acceptance_rate" in agg:
        print(f"  speculative: gamma={agg['spec_gamma']}, "
              f"{agg['spec_acceptance_rate']*100:.1f}% drafts accepted, "
              f"{agg['spec_tokens_per_step']:.2f} tokens/cycle")
    print(f"  pool: {agg['pool_utilization']*100:.0f}% pages in use at "
          f"drain, {agg['pool_evictions']} evictions")
    if eng.slo is not None:
        for rep in eng.slo.report():
            state = "VIOLATING" if rep["violating"] else "ok"
            print(f"  SLO {rep['slo']}: p{rep['percentile']:g} = "
                  f"{rep['value']:.4g} {rep['unit']} (target "
                  f"{rep['target']:g}) [{state}], "
                  f"{rep['violations']} violation(s), burn rate "
                  f"{rep['burn_rate']:.2f}")
    return eng


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--k-percent", type=float, default=50.0)
    ap.add_argument("--clip-l", type=float, default=-8.0)
    ap.add_argument("--clip-h", type=float, default=23.0)
    ap.add_argument("--mode", default="sparqle", choices=["sparqle", "dense"])
    ap.add_argument("--no-clip", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="restore float params from this checkpoint dir")
    ap.add_argument("--seed", type=int, default=0)
    # engine knobs
    ap.add_argument("--legacy", action="store_true",
                    help="fixed-batch serving path (no engine)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="0 = size the pool to fit the whole batch")
    ap.add_argument("--token-budget", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--decode-slots", type=int, default=8)
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="self-speculative decoding: LSB4-only draft "
                         "window per verify cycle (0 = off)")
    ap.add_argument("--slo", action="append", default=[],
                    help="declarative SLO spec, repeatable and/or "
                         "comma-separated (e.g. --slo ttft:p95<0.25 "
                         "--slo queue_depth:p50<4): "
                         "the engine watches the signal's sliding-window "
                         "percentile and reports violations + burn rate "
                         "(docs/observability.md)")
    ap.add_argument("--attribute", action="store_true",
                    help="attribute the compiled serving steps at warm-up "
                         "(per-step FLOPs/HBM/collective bytes + live "
                         "roofline and cost-model drift gauges)")
    ap.add_argument("--metrics-out", default="",
                    help="write the engine's metrics-registry snapshot "
                         "(JSON) here after the run (engine path only)")
    ap.add_argument("--trace-out", default="",
                    help="write the engine's Chrome trace-event JSON "
                         "here after the run — load in Perfetto / "
                         "chrome://tracing (engine path only)")
    ap.add_argument("--mesh", default="",
                    help="DATA,MODEL device mesh for the engine (e.g. "
                         "'2,4'): decode slots + pool pages shard over "
                         "the data axis, weights/KV heads tensor-"
                         "parallel over the model axis. Needs "
                         "data*model jax devices (on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N). "
                         "Token streams are bit-exact vs the default "
                         "single-device engine (docs/sharding.md).")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode; see examples/")
    serve_mesh = None
    if args.mesh:
        try:
            d, m = (int(v) for v in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh expects 'DATA,MODEL', got "
                             f"{args.mesh!r}")
        if d * m > 1:
            serve_mesh = make_smoke_mesh(data=d, model=m)
        if args.legacy:
            raise SystemExit("--mesh drives the paged engine; it has no "
                             "effect on --legacy (drop one of the two)")
    if args.legacy and (args.metrics_out or args.trace_out):
        raise SystemExit("--metrics-out/--trace-out read the paged "
                         "engine's observability bundle; the --legacy "
                         "path has none (drop one of the two)")
    if args.legacy and (args.slo or args.attribute):
        raise SystemExit("--slo/--attribute drive the paged engine's "
                         "observability; the --legacy path has none "
                         "(drop one of the two)")
    # ambient 1x1 mesh for the GSPMD tail paths (sparsity/cost-model
    # report); the engine gets the serving mesh explicitly
    mesh = make_smoke_mesh()

    with mesh_context(mesh):
        params = init_params(build_schema(cfg), jax.random.PRNGKey(args.seed))
        if args.ckpt:
            latest = store.latest_step(args.ckpt)
            params = store.restore(args.ckpt, latest,
                                   {"params": params})["params"]
        tile_k = 16 if args.smoke else 128
        qparams = quantize_model_params(
            params, w_bits=cfg.w_bits, k_percent=args.k_percent,
            clip_l=args.clip_l, clip_h=args.clip_h, mode=args.mode,
            enable_clipping=not args.no_clip, tile_k=tile_k)

        data = SyntheticLM(DataConfig(vocab=cfg.vocab,
                                      seq_len=args.prompt_len,
                                      global_batch=args.batch,
                                      seed=args.seed))
        prompts = jnp.asarray(data.batch_at(0)["tokens"])
        if cfg.family == "vlm":
            batch = {
                "patches": jax.random.normal(
                    jax.random.PRNGKey(1),
                    (args.batch, cfg.n_prefix, cfg.d_model)).astype(
                        cfg.cdtype),
                "tokens": prompts[:, :args.prompt_len - cfg.n_prefix]}
            plen = args.prompt_len
        else:
            batch = {"tokens": prompts}
            plen = args.prompt_len

        if args.legacy:
            _legacy_serve(cfg, qparams, batch, plen, args)
        else:
            try:
                M.check_paged_support(cfg)
            except NotImplementedError as e:
                raise SystemExit(
                    f"{e}\n(this arch serves via --legacy only)")
            eng = _engine_serve(cfg, qparams, list(np.asarray(prompts)),
                                args, serve_mesh=serve_mesh)
            if args.metrics_out:
                import json
                with open(args.metrics_out, "w") as f:
                    json.dump(eng.metrics_snapshot(), f, indent=1)
                print(f"  metrics snapshot -> {args.metrics_out}")
            if args.trace_out:
                eng.obs.tracer.export_chrome(args.trace_out)
                print(f"  chrome trace     -> {args.trace_out}")

        # achieved sub-precision sparsity of the hidden stream
        hidden = M.forward_hidden(cfg, qparams, batch)
        q = quantize_activations(hidden.reshape(-1, hidden.shape[-1]),
                                 bits=8, per_token=True).q
        s = float(subprecision_sparsity(q))
        print(f"MSB4 sub-precision sparsity of hidden activations: "
              f"{s*100:.1f}%")

        # analytical accelerator prediction at this sparsity (paper §5.1)
        lm = LMShape(cfg.name, cfg.n_layers, cfg.d_model,
                     max(1, cfg.n_heads), max(1, cfg.n_kv_heads),
                     max(1, cfg.d_ff or cfg.moe_d_ff), cfg.vocab,
                     w_bits=cfg.w_bits)
        rep = evaluate_model(lm, s, HardwareConfig(),
                             prefill_tokens=plen * args.batch,
                             decode_batch=args.batch)
        imp = rep.improvements()
        print("cost-model prediction at this sparsity: "
              f"TTFT -{imp['ttft_latency_pct']:.1f}%, "
              f"TPOT -{imp['tpot_latency_pct']:.1f}%, "
              f"prefill E -{imp['prefill_energy_pct']:.1f}%, "
              f"decode E -{imp['decode_energy_pct']:.1f}%")


if __name__ == "__main__":
    main()
