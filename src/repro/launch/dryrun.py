import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e): lower + compile every cell.

For every (architecture x assigned input shape) cell and both production
meshes (single-pod 16x16, multi-pod 2x16x16), this driver builds abstract
inputs (ShapeDtypeStructs — zero allocation), jits the right step function
with explicit in/out shardings, lowers, compiles, and records:

  * ``compiled.memory_analysis()``  — per-device bytes (does it fit?),
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute),

into ``runs/dryrun/<mesh>/<arch>__<shape>.json``, which
``benchmarks/roofline.py`` consumes.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multipod --list
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding

from repro.configs.base import SHAPES
from repro.distributed.sharding import mesh_context, spec_for
from repro.launch import steps as S
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.models.registry import (cell_plan, get_config, input_specs,
                                   runnable_cells)
from repro.optim.adamw import OptConfig

RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "runs", "dryrun")


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh, *,
               microbatch: Optional[int] = None,
               profile: str = "baseline",
               knob_overrides: Optional[Dict[str, Any]] = None):
    """Build + lower + compile one cell. Returns (record, compiled)."""
    from repro.distributed.sharding import profile_rules

    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    kn: Dict[str, Any] = dict(knob_overrides or {})
    rules = profile_rules(profile, cfg, shp.kind, mesh,
                          global_batch=shp.global_batch)
    rules.update(kn.pop("rules", {}))
    t0 = time.time()

    with mesh_context(mesh, rules=rules):
        if shp.kind == "train":
            # tuned: MoE archs take larger microbatches (fewer accumulation
            # steps -> fewer per-ubatch expert-weight gathers + grad psums)
            default_mb = 64 if (profile == "tuned" and cfg.n_experts) else 32
            mb = microbatch if microbatch is not None else kn.pop(
                "microbatch", default_mb)
            knobs = S.TrainKnobs(microbatch=mb, **kn)
            ocfg = OptConfig()
            step = S.make_train_step(cfg, ocfg, knobs)
            st_schema = S.train_state_schema(cfg)
            st_abs, st_shard = S.abstract_and_shardings(st_schema, mesh)
            batch_abs = input_specs(cfg, shp, "train")
            b_shard = S.batch_shardings(batch_abs, mesh)
            jitted = jax.jit(step, in_shardings=(st_shard, b_shard),
                             donate_argnums=0)
            lowered = jitted.lower(st_abs, batch_abs)

        elif shp.kind == "prefill":
            pschema = S.serve_param_schema(cfg)
            p_abs, p_shard = S.abstract_and_shardings(pschema, mesh)
            batch_abs = input_specs(cfg, shp, "prefill")
            b_shard = S.batch_shardings(batch_abs, mesh)
            step = S.make_serve_prefill(cfg, max_len=shp.seq_len)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_abs, batch_abs)

        else:  # decode
            pschema = S.serve_param_schema(cfg)
            p_abs, p_shard = S.abstract_and_shardings(pschema, mesh)
            c_abs, c_shard = S.cache_abstract_and_shardings(
                cfg, shp.global_batch, shp.seq_len, mesh)
            tok_abs = input_specs(cfg, shp, "decode")
            tp_shard = {
                k: NamedSharding(mesh, spec_for(("batch",), v.shape, mesh))
                for k, v in tok_abs.items()}
            step = S.make_serve_decode(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, tp_shard["token"],
                              tp_shard["pos"]),
                donate_argnums=1)
            lowered = jitted.lower(p_abs, c_abs, tok_abs["token"],
                                   tok_abs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax < 0.6 returns [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    t1 = time.time()
    st = analyze(compiled.as_text())
    t_analyze = time.time() - t1
    n_dev = mesh.devices.size

    # All numbers below are PER DEVICE: the partitioned HLO carries shard
    # shapes, and memory_analysis reports the per-device program.
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shp.kind,
        "profile": profile,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "flops_hlo": st.flops,                      # all dots, scan-aware
        "dot_flops_by_dtype": st.dot_flops_by_dtype,
        "hbm_bytes_hlo": st.hbm_bytes,
        "collective_bytes": st.coll_bytes,
        "collective_count": st.coll_count,
        "top_dots": [[v, k] for v, k in st.top_dots],
        "top_colls": [[v, k] for v, k in st.top_colls],
        "xla_flops": float(cost.get("flops", -1)),  # f32 ops only (CPU BE)
        "xla_bytes_accessed": float(cost.get("bytes accessed", -1)),
        "memory": {
            "argument_size_b": int(mem.argument_size_in_bytes),
            "output_size_b": int(mem.output_size_in_bytes),
            "temp_size_b": int(mem.temp_size_in_bytes),
            "generated_code_size_b": int(mem.generated_code_size_in_bytes),
        },
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
    }
    return record, compiled


def run_cells(cells, mesh_kind: str, out_dir: str,
              knob_overrides=None, profile: str = "baseline"
              ) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    os.makedirs(out_dir, exist_ok=True)
    results = {}
    for arch, shape_name in cells:
        key = f"{arch}__{shape_name}"
        path = os.path.join(out_dir, key + ".json")
        try:
            rec, compiled = lower_cell(arch, shape_name, mesh,
                                       profile=profile,
                                       knob_overrides=knob_overrides)
            del compiled
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            per_dev = (rec["memory"]["argument_size_b"]
                       + rec["memory"]["temp_size_b"])
            print(f"OK   {mesh_kind:9s} {key:42s} "
                  f"flops/dev={rec['flops_hlo']:.3e} "
                  f"coll/dev={rec['collective_bytes'].get('total', 0):.3e}B "
                  f"mem/dev={per_dev/2**30:.2f}GiB "
                  f"compile={rec['compile_s']}s", flush=True)
            results[key] = rec
        except Exception as e:  # noqa: BLE001 — report, continue, fail at end
            print(f"FAIL {mesh_kind:9s} {key}: {e}", flush=True)
            traceback.print_exc()
            results[key] = {"error": str(e)}
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["singlepod", "multipod", "both"])
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "tuned"])
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=RUNS_DIR)
    args = ap.parse_args()

    cells = runnable_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if args.list:
        for arch, s in cells:
            print(arch, s)
        for arch in sorted({a for a, _ in runnable_cells()}):
            for sname, runs, why in cell_plan(arch):
                if not runs:
                    print(f"SKIP {arch} {sname}: {why}")
        return

    meshes = (["singlepod", "multipod"] if args.mesh == "both"
              else [args.mesh])
    n_fail = 0
    for mk in meshes:
        sub = mk if args.profile == "baseline" else f"{mk}-{args.profile}"
        res = run_cells(cells, mk, os.path.join(args.out, sub),
                        profile=args.profile)
        n_fail += sum(1 for r in res.values() if "error" in r)
    print(f"\ndry-run complete; {n_fail} failures")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
