"""Static analyzer for optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` on the CPU backend undercounts our graphs in
two ways that matter for the roofline: integer dot_generals (the SPARQLe
int8 dual-pass matmuls) are not "flops", and ops inside ``while`` bodies
(the layer scan, the grad-accumulation scan, flash-attention block scans)
must be multiplied by their trip counts. This module walks the HLO call
graph with per-computation execution multipliers and produces:

  * ``flops``        — 2*M*N*K summed over every dot (any element type),
  * ``coll_bytes``   — payload bytes per collective kind (result shapes),
  * ``hbm_bytes``    — sum of operand+result bytes of every *top-level* op
                       (fusion internals excluded — a fusion is the unit
                       that reads/writes HBM), a structural proxy for Hh
                       HBM traffic;
  * per-op tallies for §Perf iteration (e.g. count of all-gathers of the
    same tensor, dominant dot shapes).

All shapes in partitioned HLO are per-device shard shapes, so every number
is *per device* — matching roofline terms normalized per chip.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

# bytes per element; sub-byte dtypes (XLA packs two s4/u4 per byte, four
# s2/u2) carry fractional sizes — shape_bytes returns floats
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "s4": 0.5, "u4": 0.5,
    "s2": 0.25, "u2": 0.25, "u1": 0.125,
}
# shapes that carry no payload bytes (control tokens, opaque handles)
_ZERO_SIZE_DTYPES = {"token", "opaque"}
# what a dtype token looks like — used to separate genuinely-unknown
# dtypes from incidental `word[digits]` text (slice bounds etc.)
_DTYPE_LIKE_RE = re.compile(r"^(?:pred|token|opaque|bf\d+|[sufc]\d+\w*)$")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# shape group is lazy; the opcode must be a word immediately followed by '('
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_CALLEE_RE = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)="
    r"%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")


def shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    """All (dtype, dims) element shapes inside a (possibly tuple) shape."""
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(shape_str)
            if m.group(1) in _DTYPE_BYTES]


def unknown_dtypes_in(shape_str: str) -> List[str]:
    """Dtype-looking tokens in a shape string the byte table can't size.

    A nonempty return means ``shape_bytes`` silently dropped elements —
    the analyzer records these on :class:`HloStats` and ``analyze(...,
    strict=True)`` turns them into a hard error instead of undercounted
    HBM bytes.
    """
    return [m.group(1) for m in _SHAPE_RE.finditer(shape_str)
            if m.group(1) not in _DTYPE_BYTES
            and m.group(1) not in _ZERO_SIZE_DTYPES
            and _DTYPE_LIKE_RE.match(m.group(1))]


def shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str          # operands + attributes (raw text)

    @property
    def operand_text(self) -> str:
        """Text of the operand list (up to the matching close paren)."""
        depth = 1
        for i, c in enumerate(self.rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return self.rest[:i]
        return self.rest


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion_body: bool = False


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    """Parse computations; returns ({name: comp}, entry_name)."""
    comps, entry, _ = parse_hlo_ex(text)
    return comps, entry


def parse_hlo_ex(text: str) -> Tuple[Dict[str, Computation],
                                     Optional[str], List[str]]:
    """Parse computations, also returning the unparsed op lines.

    The third element lists every ``name = ...`` line *inside* a
    computation body that the op regex failed to match — ops the walker
    would otherwise silently skip (module headers and scheduling
    annotations outside computations are not ops and are not counted).
    """
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    unparsed: List[str] = []
    for line in text.splitlines():
        s = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{$", s)
        if header and not s.startswith("//"):
            cur = Computation(header.group(2), [])
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2).strip(), m.group(3),
                              m.group(4)))
        elif "=" in s and not s.startswith(("//", "#")):
            unparsed.append(f"{cur.name}: {s}")
    return comps, entry, unparsed


def _trip_count(op: Op, comps: Dict[str, Computation],
                cond_name: Optional[str]) -> int:
    """Trip count of a while: XLA's known_trip_count backend config, or the
    largest constant in the condition computation as a fallback."""
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    if cond_name and cond_name in comps:
        consts = []
        for cop in comps[cond_name].ops:
            mm = _CONST_RE.search(cop.opcode + "(" + cop.rest)
            if cop.opcode == "constant":
                mm = re.search(r"^(\d+)\)", cop.rest)
            if mm:
                consts.append(int(mm.group(1)))
        if consts:
            return max(consts)
    return 1


def _callees(op: Op) -> List[str]:
    names = [m for m in _CALLEE_RE.findall(op.rest)]
    bm = _BRANCH_RE.search(op.rest)
    if bm:
        names += [n.strip().lstrip("%") for n in bm.group(1).split(",")]
    return names


def compute_multipliers(comps: Dict[str, Computation],
                        entry: str) -> Dict[str, float]:
    """Execution count per computation, walking from ENTRY through
    while(body x trip), fusion/call/reduce (x1), conditionals (x1)."""
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, k: float):
        if k <= 0 or name not in comps:
            return
        mult[name] += k
        comp = comps[name]
        for op in comp.ops:
            if op.opcode == "while":
                body = cond = None
                for mm in re.finditer(r"(body|condition)=%?([\w\.\-]+)",
                                      op.rest):
                    if mm.group(1) == "body":
                        body = mm.group(2)
                    else:
                        cond = mm.group(2)
                trips = _trip_count(op, comps, cond)
                if body:
                    visit(body, k * trips)
                if cond:
                    visit(cond, k * (trips + 1))
            elif op.opcode == "fusion":
                for c in _callees(op):
                    comps[c].is_fusion_body = True
                    # fusion internals: counted for flops, not for HBM
                    visit(c, k)
            else:
                for c in _callees(op):
                    visit(c, k)

    visit(entry, 1.0)
    return dict(mult)


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    """2 * prod(output dims) * prod(contracting dims)."""
    out = shape_dims(op.shape)
    if not out:
        return 0.0
    _, out_dims = out[0]
    out_n = 1
    for d in out_dims:
        out_n *= d
    # contracting dims from the lhs operand's shape
    lhs_name_m = _OPERAND_RE.search(op.operand_text)
    cdims_m = _CONTRACT_RE.search(op.rest)
    k = 1
    if lhs_name_m and cdims_m:
        lhs_shape = symtab.get(lhs_name_m.group(1), "")
        dims = shape_dims(lhs_shape)
        if dims:
            _, ld = dims[0]
            for ci in (int(c) for c in cdims_m.group(1).split(",") if c):
                if ci < len(ld):
                    k *= ld[ci]
    return 2.0 * out_n * k


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops_by_dtype: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    top_dots: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    top_colls: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    # coverage accounting: dtypes the byte table could not size (per-op
    # occurrence counts) and op lines the parser could not match —
    # nonempty means the byte/flop totals above undercount
    unknown_dtypes: Dict[str, int] = dataclasses.field(default_factory=dict)
    unparsed_ops: List[str] = dataclasses.field(default_factory=list)

    @property
    def complete(self) -> bool:
        """True when every op parsed and every dtype was sized."""
        return not self.unknown_dtypes and not self.unparsed_ops


class HloCoverageError(ValueError):
    """``analyze(strict=True)`` found ops or dtypes it cannot account."""


def analyze(text: str, strict: bool = False) -> HloStats:
    """Walk optimized HLO text into :class:`HloStats`.

    ``strict=True`` raises :class:`HloCoverageError` when the module
    contains unparsed op lines or dtypes missing from the byte table,
    instead of returning silently-undercounted totals — the mode the
    serving attribution layer uses, where a skipped op means the
    roofline gauges lie.
    """
    comps, entry, unparsed = parse_hlo_ex(text)
    if entry is None:
        if strict:
            raise HloCoverageError("no ENTRY computation found in HLO text")
        return HloStats()
    mult = compute_multipliers(comps, entry)
    stats = HloStats()
    dot_acc: Dict[str, float] = defaultdict(float)
    coll_acc: Dict[str, float] = defaultdict(float)

    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        if k <= 0:
            continue
        symtab = {op.name: op.shape for op in comp.ops}
        for op in comp.ops:
            if op.opcode == "dot":
                f = _dot_flops(op, symtab) * k
                stats.flops += f
                dt = shape_dims(op.shape)
                key = dt[0][0] if dt else "?"
                stats.dot_flops_by_dtype[key] = (
                    stats.dot_flops_by_dtype.get(key, 0.0) + f)
                dot_acc[f"{op.shape} x{int(k)}"] += f
            elif op.opcode in _COLLECTIVES:
                b = shape_bytes(op.shape) * k
                stats.coll_bytes[op.opcode] = (
                    stats.coll_bytes.get(op.opcode, 0.0) + b)
                stats.coll_count[op.opcode] = (
                    stats.coll_count.get(op.opcode, 0.0) + k)
                coll_acc[f"{op.opcode} {op.shape} x{int(k)}"] += b
            # HBM proxy: top-level ops only (fusion bodies don't touch HBM)
            if not comp.is_fusion_body and op.opcode not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional", "call"):
                operand_b = 0
                for on in _OPERAND_RE.findall(op.operand_text):
                    if on in symtab:
                        operand_b += shape_bytes(symtab[on])
                stats.hbm_bytes += (operand_b + shape_bytes(op.shape)) * k
    stats.coll_bytes["total"] = sum(stats.coll_bytes.values())
    stats.top_dots = sorted(((v, k) for k, v in dot_acc.items()),
                            reverse=True)[:12]
    stats.top_colls = sorted(((v, k) for k, v in coll_acc.items()),
                             reverse=True)[:12]
    stats.unparsed_ops = unparsed
    unk: Dict[str, int] = defaultdict(int)
    for comp in comps.values():
        for op in comp.ops:
            toks = unknown_dtypes_in(op.shape)
            if (not toks and "[" in op.shape and not shape_dims(op.shape)
                    and not any(z in op.shape
                                for z in _ZERO_SIZE_DTYPES)):
                # result shape sized to zero ops: a dtype so exotic it
                # doesn't even look like one still must not pass silently
                head = op.shape.split("[", 1)[0].strip()
                toks = [head.split()[-1] if head else "?"]
            for dt in toks:
                unk[dt] += 1
    stats.unknown_dtypes = dict(unk)
    if strict and not stats.complete:
        detail = []
        if stats.unknown_dtypes:
            detail.append(f"unknown dtypes {stats.unknown_dtypes}")
        if stats.unparsed_ops:
            sample = "; ".join(stats.unparsed_ops[:3])
            detail.append(f"{len(stats.unparsed_ops)} unparsed op "
                          f"line(s), e.g. {sample!r}")
        raise HloCoverageError("HLO coverage incomplete: "
                               + "; ".join(detail))
    return stats
