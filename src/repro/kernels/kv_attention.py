"""Pallas TPU kernel: fused decode attention over a packed-KV4 cache.

The serving hot loop after the §Perf tuning is decode attention streaming
the quantized KV cache (EXPERIMENTS.md Cell A: memory-bound at the
weights+cache stream). This kernel keeps the cache in its wire format end
to end: int4 nibbles packed two-per-byte are DMA'd into VMEM, unpacked and
dequantized in-register, and consumed by a blockwise online-softmax
attention — the cache never exists in HBM at bf16 width, which is what
halves the dominant decode stream (the XLA path materializes the
dequantized cache between ops unless fusion cooperates; the kernel makes
the fusion structural).

Layout: one grid step handles one (batch, kv-head) pair and one cache
block of ``bs`` tokens (innermost, 'arbitrary'): running max/denominator
and the (G, hd) output accumulator live in VMEM scratch across the cache
scan — the standard flash-decoding structure re-tiled for VMEM.

Three entry points share the same kernel body (``_flash_step``):

  * :func:`kv4_decode_attention`        — contiguous (B, S, KVH, …) cache;
  * :func:`kv4_paged_decode_attention`  — a paged pool (P, page, KVH, …)
    walked through a per-sequence block table (scalar-prefetched so the
    page index feeds the DMA index map). Because the body, block shapes
    and accumulation order are identical, the paged variant is bit-exact
    against the contiguous one when the pages tile the same cache.
  * :func:`kv4_paged_verify_attention`  — the multi-token (q > 1) variant
    for self-speculative verification: T window tokens per sequence, each
    causally masked to its own absolute position ``pos + t``. The window
    axis is a *grid* dimension, so every (b, h, t) cell runs the exact
    single-token computation (same block shapes, same dot shapes, same
    accumulation order) — bit-exact against a loop of T single-token
    paged decode calls by construction.

A fourth entry point, :func:`kv_tiered_paged_decode_attention`, extends
the paged decode variant with the KV2 precision-ladder read path: a
second scalar-prefetched table carries a per-page tier id, the index maps
route each grid step's DMA to the KV4 or KV2 slab accordingly, and the
body selects the dequantized block by tier — bit-exact against
``kv4_paged_decode_attention`` whenever every page is tier 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

NEG_INF = -2.0e38


def _unpack4(q):  # int8 packed nibbles -> two sign-extended int8 planes
    lo = jnp.right_shift(jnp.left_shift(q, 4), 4)
    hi = jnp.right_shift(q, 4)
    return lo, hi


def _unpack2(q):  # int8 packed 2-bit fields -> four sign-extended planes
    f0 = jnp.right_shift(jnp.left_shift(q, 6), 6)
    f1 = jnp.right_shift(jnp.left_shift(q, 4), 6)
    f2 = jnp.right_shift(jnp.left_shift(q, 2), 6)
    f3 = jnp.right_shift(q, 6)
    return f0, f1, f2, f3


def _dequant4_block(q_ref, s_ref, bs):
    """Unpack + dequantize one packed-int4 cache block in VMEM -> (bs, hd)."""
    qq = q_ref[...].reshape(bs, -1)                       # (bs, hd//2) int8
    ss = s_ref[...].reshape(bs)
    lo, hi = _unpack4(qq)
    x_int = jnp.stack([lo, hi], axis=-1).reshape(bs, -1)  # (bs, hd)
    return x_int.astype(jnp.float32) * ss[:, None]


def _dequant2_block(q_ref, s_ref, bs):
    """Unpack + dequantize one packed-int2 (KV2 tier) block -> (bs, hd)."""
    qq = q_ref[...].reshape(bs, -1)                       # (bs, hd//4) int8
    ss = s_ref[...].reshape(bs)
    f0, f1, f2, f3 = _unpack2(qq)
    x_int = jnp.stack([f0, f1, f2, f3], axis=-1).reshape(bs, -1)
    return x_int.astype(jnp.float32) * ss[:, None]


def _flash_core(pos, s_idx, q_ref, k, v, out_ref, m_ref, l_ref, acc_ref,
                *, n_s: int, bs: int, scale: float):
    """The online-softmax scan step on dequantized (bs, hd) k/v blocks.
    Every entry point feeds this same f32 computation, so two call paths
    that hand it elementwise-identical k/v produce identical bits."""
    hd = out_ref.shape[-1]

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].reshape(-1, hd).astype(jnp.float32)   # (G, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # causal validity: absolute cache position <= pos
    j = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(j <= pos, s, NEG_INF)                  # (G, bs)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _drain():
        out_ref[...] = (acc_ref[...] /
                        jnp.maximum(l_ref[...], 1e-30)).astype(
                            out_ref.dtype).reshape(out_ref.shape)


def _flash_step(pos, s_idx, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, out_ref,
                m_ref, l_ref, acc_ref, *, n_s: int, bs: int, scale: float):
    """One cache-block step of the online-softmax scan for ONE query row
    group. ``pos`` is the query's absolute position (a scalar), ``s_idx``
    its place along the cache-block grid axis — every entry point maps its
    own grid onto these two values, so the f32 computation (and therefore
    the bits) is identical across layouts.
    """
    # unpack + dequantize this cache block in VMEM
    k = _dequant4_block(kq_ref, ks_ref, bs)
    v = _dequant4_block(vq_ref, vs_ref, bs)
    _flash_core(pos, s_idx, q_ref, k, v, out_ref, m_ref, l_ref, acc_ref,
                n_s=n_s, bs=bs, scale=scale)


def _kernel(pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, out_ref,
            m_ref, l_ref, acc_ref, *, n_s: int, bs: int, scale: float):
    _flash_step(pos_ref[0], pl.program_id(2), q_ref, kq_ref, ks_ref,
                vq_ref, vs_ref, out_ref, m_ref, l_ref, acc_ref,
                n_s=n_s, bs=bs, scale=scale)


@functools.partial(jax.jit,
                   static_argnames=("bs", "interpret"))
def kv4_decode_attention(
    q: jax.Array,       # (B, KVH, G, hd) — grouped query heads
    k_q: jax.Array,     # (B, S, KVH, hd//2) int8, packed nibbles
    k_s: jax.Array,     # (B, S, KVH) f32 per-token-head scales
    v_q: jax.Array,     # (B, S, KVH, hd//2) int8
    v_s: jax.Array,     # (B, S, KVH) f32
    pos: jax.Array,     # (B,) int32 — current position (inclusive)
    *,
    bs: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, KVH, G, hd) attention output. Cache stays packed-int4
    in HBM; unpack+dequant are fused into the attention block scan."""
    b, kvh, g, hd = q.shape
    _, s, _, hdp = k_q.shape
    assert hdp * 2 == hd, (hd, hdp)
    assert s % bs == 0, (s, bs)
    n_s = s // bs
    scale = hd ** -0.5

    grid = (b, kvh, n_s)
    return pl.pallas_call(
        functools.partial(_kernel, n_s=n_s, bs=bs, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, isb: (ib,)),           # pos
            pl.BlockSpec((1, 1, g, hd), lambda ib, ih, isb: (ib, ih, 0, 0)),
            pl.BlockSpec((1, bs, 1, hdp),
                         lambda ib, ih, isb: (ib, isb, ih, 0)),      # k_q
            pl.BlockSpec((1, bs, 1), lambda ib, ih, isb: (ib, isb, ih)),
            pl.BlockSpec((1, bs, 1, hdp),
                         lambda ib, ih, isb: (ib, isb, ih, 0)),      # v_q
            pl.BlockSpec((1, bs, 1), lambda ib, ih, isb: (ib, isb, ih)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda ib, ih, isb: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max
            pltpu.VMEM((g, 1), jnp.float32),     # running denominator
            pltpu.VMEM((g, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(pos, q, k_q, k_s, v_q, v_s)


def _paged_kernel(bt_ref, pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                  out_ref, m_ref, l_ref, acc_ref, *, n_s, bs, scale):
    # the block table only drives the index maps; the body is the shared
    # flash-decoding step (bit-exact with the contiguous layout)
    del bt_ref
    _kernel(pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, out_ref,
            m_ref, l_ref, acc_ref, n_s=n_s, bs=bs, scale=scale)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv4_paged_decode_attention(
    q: jax.Array,             # (B, KVH, G, hd) — grouped query heads
    k_pages: jax.Array,       # (P, ps, KVH, hd//2) int8, packed nibbles
    k_scale_pages: jax.Array, # (P, ps, KVH) f32 per-token-head scales
    v_pages: jax.Array,       # (P, ps, KVH, hd//2) int8
    v_scale_pages: jax.Array, # (P, ps, KVH) f32
    block_tables: jax.Array,  # (B, Pmax) int32 — seq-order page ids
    pos: jax.Array,           # (B,) int32 — current position (inclusive)
    *,
    interpret: bool = True,
) -> jax.Array:
    """Decode attention over a *paged* packed-KV4 pool.

    ``block_tables[b, i]`` names the physical page holding sequence ``b``'s
    tokens ``[i*ps, (i+1)*ps)``. Entries past the sequence's last page may
    point anywhere (conventionally the null page 0): the absolute-position
    causal mask ``i*ps + offset <= pos[b]`` discards them. The table is
    scalar-prefetched so page ids are available to the DMA index maps —
    the pool is only ever touched one page per grid step, in wire format.
    """
    b, kvh, g, hd = q.shape
    n_pages, ps, _, hdp = k_pages.shape
    _, n_s = block_tables.shape
    assert hdp * 2 == hd, (hd, hdp)
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, isb, bt: (ib,)),        # pos
            pl.BlockSpec((1, 1, g, hd),
                         lambda ib, ih, isb, bt: (ib, ih, 0, 0)),     # q
            pl.BlockSpec((1, ps, 1, hdp),
                         lambda ib, ih, isb, bt: (bt[ib, isb], 0, ih, 0)),
            pl.BlockSpec((1, ps, 1),
                         lambda ib, ih, isb, bt: (bt[ib, isb], 0, ih)),
            pl.BlockSpec((1, ps, 1, hdp),
                         lambda ib, ih, isb, bt: (bt[ib, isb], 0, ih, 0)),
            pl.BlockSpec((1, ps, 1),
                         lambda ib, ih, isb, bt: (bt[ib, isb], 0, ih)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda ib, ih, isb, bt: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max
            pltpu.VMEM((g, 1), jnp.float32),     # running denominator
            pltpu.VMEM((g, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, n_s=n_s, bs=ps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(block_tables, pos, q, k_pages, k_scale_pages, v_pages, v_scale_pages)


def _paged_verify_kernel(bt_ref, pos_ref, q_ref, kq_ref, ks_ref, vq_ref,
                         vs_ref, out_ref, m_ref, l_ref, acc_ref, *, n_s,
                         bs, scale):
    # grid = (B, KVH, T, n_s): window token t's query position is pos + t;
    # everything else is the shared single-token flash step, so cell
    # (b, h, t) computes exactly what a single-token decode at pos + t
    # would (bit-exact vs a loop of kv4_paged_decode_attention calls)
    del bt_ref
    _flash_step(pos_ref[0] + pl.program_id(2), pl.program_id(3), q_ref,
                kq_ref, ks_ref, vq_ref, vs_ref, out_ref, m_ref, l_ref,
                acc_ref, n_s=n_s, bs=bs, scale=scale)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv4_paged_verify_attention(
    q: jax.Array,             # (B, T, KVH, G, hd) — T window tokens/seq
    k_pages: jax.Array,       # (P, ps, KVH, hd//2) int8, packed nibbles
    k_scale_pages: jax.Array, # (P, ps, KVH) f32 per-token-head scales
    v_pages: jax.Array,       # (P, ps, KVH, hd//2) int8
    v_scale_pages: jax.Array, # (P, ps, KVH) f32
    block_tables: jax.Array,  # (B, Pmax) int32 — seq-order page ids
    pos: jax.Array,           # (B,) int32 — position of window token 0
    *,
    interpret: bool = True,
) -> jax.Array:
    """Multi-token decode attention for speculative verification.

    Scores a whole draft window in one batched call: window token ``t``
    of sequence ``b`` sits at absolute position ``pos[b] + t`` and
    attends to cache positions ``<= pos[b] + t`` (so it sees the other
    window tokens' K/V — the caller writes the window's K/V into the
    pages *before* this call — but never its own future). Returns
    (B, T, KVH, G, hd).

    The window axis is a grid dimension, not a wider query block: each
    (b, h, t) grid cell replays the single-token kernel body with the
    same block and dot shapes, which makes the output bit-exact against
    T sequential :func:`kv4_paged_decode_attention` calls.
    """
    b, t, kvh, g, hd = q.shape
    n_pages, ps, _, hdp = k_pages.shape
    _, n_s = block_tables.shape
    assert hdp * 2 == hd, (hd, hdp)
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, t, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, it, isb, bt: (ib,)),    # pos
            pl.BlockSpec((1, 1, 1, g, hd),
                         lambda ib, ih, it, isb, bt: (ib, it, ih, 0, 0)),
            pl.BlockSpec((1, ps, 1, hdp),
                         lambda ib, ih, it, isb, bt: (bt[ib, isb], 0, ih, 0)),
            pl.BlockSpec((1, ps, 1),
                         lambda ib, ih, it, isb, bt: (bt[ib, isb], 0, ih)),
            pl.BlockSpec((1, ps, 1, hdp),
                         lambda ib, ih, it, isb, bt: (bt[ib, isb], 0, ih, 0)),
            pl.BlockSpec((1, ps, 1),
                         lambda ib, ih, it, isb, bt: (bt[ib, isb], 0, ih)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, g, hd),
                               lambda ib, ih, it, isb, bt: (ib, it, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max
            pltpu.VMEM((g, 1), jnp.float32),     # running denominator
            pltpu.VMEM((g, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_verify_kernel, n_s=n_s, bs=ps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, kvh, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(block_tables, pos, q, k_pages, k_scale_pages, v_pages, v_scale_pages)


def _tiered_paged_kernel(bt_ref, tt_ref, pos_ref, q_ref, kq_ref, ks_ref,
                         vq_ref, vs_ref, k2q_ref, k2s_ref, v2q_ref, v2s_ref,
                         out_ref, m_ref, l_ref, acc_ref, *, n_s, bs, scale):
    # per-page tier routing: the index maps already DMA'd the right slab
    # block (the other slab's block is its null page); the body dequantizes
    # both candidates and selects by the prefetched tier id. On a tier-0
    # page the selected f32 values are elementwise identical to what
    # _flash_step computes, so the shared core produces identical bits.
    tier = tt_ref[pl.program_id(0), pl.program_id(2)]
    k4 = _dequant4_block(kq_ref, ks_ref, bs)
    v4 = _dequant4_block(vq_ref, vs_ref, bs)
    k2 = _dequant2_block(k2q_ref, k2s_ref, bs)
    v2 = _dequant2_block(v2q_ref, v2s_ref, bs)
    k = jnp.where(tier == 1, k2, k4)
    v = jnp.where(tier == 1, v2, v4)
    _flash_core(pos_ref[0], pl.program_id(2), q_ref, k, v, out_ref,
                m_ref, l_ref, acc_ref, n_s=n_s, bs=bs, scale=scale)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_tiered_paged_decode_attention(
    q: jax.Array,              # (B, KVH, G, hd) — grouped query heads
    k_pages: jax.Array,        # (P, ps, KVH, hd//2) int8, packed nibbles
    k_scale_pages: jax.Array,  # (P, ps, KVH) f32 per-token-head scales
    v_pages: jax.Array,        # (P, ps, KVH, hd//2) int8
    v_scale_pages: jax.Array,  # (P, ps, KVH) f32
    k2_pages: jax.Array,       # (P2, ps, KVH, hd//4) int8, 2-bit fields
    k2_scale_pages: jax.Array,  # (P2, ps, KVH) f32
    v2_pages: jax.Array,       # (P2, ps, KVH, hd//4) int8
    v2_scale_pages: jax.Array,  # (P2, ps, KVH) f32
    block_tables: jax.Array,   # (B, Pmax) int32 — seq-order page ids
    tier_tables: jax.Array,    # (B, Pmax) int32 — per-page tier (0/1)
    pos: jax.Array,            # (B,) int32 — current position (inclusive)
    *,
    interpret: bool = True,
) -> jax.Array:
    """Mixed-tier decode attention over the KV4 + KV2 page slabs.

    The precision ladder's read path: ``tier_tables[b, i]`` says which
    slab ``block_tables[b, i]`` indexes — 0 for the packed-int4 pool,
    1 for the packed-int2 (demoted) pool. Both tables are scalar-
    prefetched; each grid step DMAs one page from the slab the tier
    selects (the other slab contributes only its reserved null page 0)
    and the body picks the dequantized block by tier id. Undemoted
    pages therefore flow through the exact f32 computation of
    :func:`kv4_paged_decode_attention` — an all-tier-0 call is bit-exact
    against it — while demoted pages stream at int2 width with their
    original scales (clamp error bound in docs/format.md).
    """
    b, kvh, g, hd = q.shape
    n_pages, ps, _, hdp = k_pages.shape
    _, n_s = block_tables.shape
    assert hdp * 2 == hd, (hd, hdp)
    assert k2_pages.shape[-1] * 4 == hd, (hd, k2_pages.shape)
    scale = hd ** -0.5

    def kv4_map(ib, ih, isb, bt, tt):
        return (jnp.where(tt[ib, isb] == 1, 0, bt[ib, isb]), 0, ih, 0)

    def kv4_smap(ib, ih, isb, bt, tt):
        return (jnp.where(tt[ib, isb] == 1, 0, bt[ib, isb]), 0, ih)

    def kv2_map(ib, ih, isb, bt, tt):
        return (jnp.where(tt[ib, isb] == 1, bt[ib, isb], 0), 0, ih, 0)

    def kv2_smap(ib, ih, isb, bt, tt):
        return (jnp.where(tt[ib, isb] == 1, bt[ib, isb], 0), 0, ih)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, isb, bt, tt: (ib,)),    # pos
            pl.BlockSpec((1, 1, g, hd),
                         lambda ib, ih, isb, bt, tt: (ib, ih, 0, 0)),  # q
            pl.BlockSpec((1, ps, 1, hdp), kv4_map),                   # k_q
            pl.BlockSpec((1, ps, 1), kv4_smap),                       # k_s
            pl.BlockSpec((1, ps, 1, hdp), kv4_map),                   # v_q
            pl.BlockSpec((1, ps, 1), kv4_smap),                       # v_s
            pl.BlockSpec((1, ps, 1, hd // 4), kv2_map),               # k2_q
            pl.BlockSpec((1, ps, 1), kv2_smap),                       # k2_s
            pl.BlockSpec((1, ps, 1, hd // 4), kv2_map),               # v2_q
            pl.BlockSpec((1, ps, 1), kv2_smap),                       # v2_s
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda ib, ih, isb, bt, tt: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max
            pltpu.VMEM((g, 1), jnp.float32),     # running denominator
            pltpu.VMEM((g, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_tiered_paged_kernel, n_s=n_s, bs=ps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(block_tables, tier_tables, pos, q, k_pages, k_scale_pages,
      v_pages, v_scale_pages, k2_pages, k2_scale_pages,
      v2_pages, v2_scale_pages)
