"""Public jit'd wrappers over the Pallas kernels.

``sparqle_linear`` is the framework's quantized-linear entry point. It hides
tile padding, backend selection and the encode step:

  * ``backend='pallas'``  — Pallas kernels (interpret=True on CPU; the real
    TPU target when run on TPU devices);
  * ``backend='xla'``     — the pure-XLA dual-pass path
    (``core.sparse_matmul``), used inside pjit'd distributed graphs.

Both backends implement the identical numerical contract (kernels/ref.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack_nibbles
from repro.core.quantize import QuantizedTensor, quantize_activations
from repro.core.sparqle import SparqleActivation, encode, tile_population
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.sparqle_matmul import (
    DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, sparqle_matmul,
    sparqle_matmul_packed)


def _pad_to(x: jax.Array, mult: tuple) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def sparqle_linear(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    col_mask: Optional[jax.Array] = None,
    clip_l: Optional[jax.Array] = None,
    clip_h: Optional[jax.Array] = None,
    backend: str = "pallas",
    wire_format: str = "unpacked",
    msb_skip: bool = False,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Quantize -> (clip) -> decompose -> dual-pass matmul. x: (..., K).

    ``wire_format='packed'`` streams the activation nibble planes in the
    two-per-byte wire layout (``sparqle_matmul_packed`` unpacks in-VMEM);
    bit-exact vs ``'unpacked'`` — same kernel body, half the DMA bytes.
    ``msb_skip`` runs the 1-round LSB4-only draft forward (the sparse MSB
    pass is statically elided from the kernel): the output is what you
    would get dequantizing the LSB plane alone.
    """
    from repro.core.clipping import apply_clipping

    orig = x.shape
    k_in = orig[-1]
    n_out = w.q.shape[-1]
    x2 = x.reshape(-1, k_in)
    m = x2.shape[0]

    qa = quantize_activations(x2, bits=8, per_token=True)
    q = qa.q
    if col_mask is not None and clip_l is not None:
        q = apply_clipping(q, col_mask, clip_l, clip_h)

    assert wire_format in ("unpacked", "packed"), wire_format
    if backend == "xla":
        if wire_format == "packed":
            # the wire layout, not the dense int8 tensor, feeds the matmul
            from repro.core.packing import encode_packed, unpack_planes
            act = unpack_planes(encode_packed(q))
        else:
            act = encode(q, 1.0)
        from repro.core.sparse_matmul import sparqle_matmul_xla
        msb = jnp.zeros_like(act.msb4) if msb_skip else act.msb4
        pbm = jnp.zeros_like(act.pbm) if msb_skip else act.pbm
        out = sparqle_matmul_xla(
            SparqleActivation(act.lsb4, msb, pbm, jnp.float32(1.0)),
            QuantizedTensor(w.q, jnp.ones_like(w.scale), w.zero, w.bits))
        out = out * qa.scale * w.scale.reshape(1, -1)
        return out.reshape(*orig[:-1], n_out).astype(x.dtype)

    # pallas path: pad everything to tile multiples
    act = encode(q, 1.0)
    lsb = _pad_to(act.lsb4, (bm, bk))
    msb = _pad_to(act.msb4, (bm, bk))
    pbm = _pad_to(act.pbm, (bm, bk))
    wq = _pad_to(w.q.astype(jnp.int8), (bk, bn))
    asc = _pad_to(qa.scale.reshape(-1, 1).astype(jnp.float32), (bm, 1))
    wsc = _pad_to(w.scale.reshape(1, -1).astype(jnp.float32), (1, bn))
    pop = tile_population(pbm, bm, bk)
    if wire_format == "packed":
        out = sparqle_matmul_packed(
            pack_nibbles(lsb), pack_nibbles(msb), pop, wq, asc, wsc,
            bm=bm, bn=bn, bk=bk, interpret=interpret, msb_skip=msb_skip)
    else:
        out = sparqle_matmul(lsb, msb, pop, wq, asc, wsc,
                             bm=bm, bn=bn, bk=bk, interpret=interpret,
                             msb_skip=msb_skip)
    out = out[:m, :n_out]
    return out.reshape(*orig[:-1], n_out).astype(x.dtype)


def dense_quant_linear(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Baseline dense W4A8 linear (no SPARQLe decomposition)."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    m = x2.shape[0]
    n_out = w.q.shape[-1]
    qa = quantize_activations(x2, bits=8, per_token=True)
    a = _pad_to(qa.q, (bm, bk))
    wq = _pad_to(w.q.astype(jnp.int8), (bk, bn))
    asc = _pad_to(qa.scale.reshape(-1, 1).astype(jnp.float32), (bm, 1))
    wsc = _pad_to(w.scale.reshape(1, -1).astype(jnp.float32), (1, bn))
    out = quant_matmul(a, wq, asc, wsc, bm=bm, bn=bn, bk=bk,
                       interpret=interpret)
    out = out[:m, :n_out]
    return out.reshape(*orig[:-1], n_out).astype(x.dtype)
