"""Public jit'd wrappers over the Pallas kernels.

``sparqle_linear`` is the framework's quantized-linear entry point. It hides
tile padding, backend selection and the encode step:

  * ``backend='pallas'``  — Pallas kernels (interpret=True on CPU; the real
    TPU target when run on TPU devices);
  * ``backend='xla'``     — the pure-XLA dual-pass path
    (``core.sparse_matmul``), used inside pjit'd distributed graphs.

Both backends implement the identical numerical contract (kernels/ref.py).

``sparqle_linear_sharded`` runs the same kernels under ``shard_map`` with
the weight partitioned on a mesh axis — column-parallel (output channels
sharded; exact by construction) or row-parallel (K sharded; global
per-token scale via an exact pmax, then ONE int32 psum of the merged
dual-pass accumulator before the drain-path rescale, so the result is
bit-identical to the unsharded call). Both wire formats and the
``msb_skip`` draft dispatch shard the same way.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.packing import pack_nibbles
from repro.core.quantize import QuantizedTensor, quantize_activations
from repro.core.sparqle import SparqleActivation, encode, tile_population
from repro.distributed.tp import shard_map_compat
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.sparqle_matmul import (
    DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, sparqle_matmul,
    sparqle_matmul_packed)


def _pad_to(x: jax.Array, mult: tuple) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _padded_kernel_call(q, w_q, a_scale, w_scale, *, wire_format, msb_skip,
                        bm, bn, bk, interpret, acc_out=False):
    """Encode int8 activations, tile-pad, dispatch the kernel, un-pad.

    Shared by the single-device and shard_map'd entry points, so sharded
    shards run the exact per-tile computation of the unsharded kernel
    (padding contributes zero to the int32 accumulator either way).
    """
    m, _ = q.shape
    n_out = w_q.shape[-1]
    act = encode(q, 1.0)
    lsb = _pad_to(act.lsb4, (bm, bk))
    msb = _pad_to(act.msb4, (bm, bk))
    pbm = _pad_to(act.pbm, (bm, bk))
    wq = _pad_to(w_q.astype(jnp.int8), (bk, bn))
    asc = _pad_to(a_scale.reshape(-1, 1).astype(jnp.float32), (bm, 1))
    wsc = _pad_to(w_scale.reshape(1, -1).astype(jnp.float32), (1, bn))
    pop = tile_population(pbm, bm, bk)
    if wire_format == "packed":
        out = sparqle_matmul_packed(
            pack_nibbles(lsb), pack_nibbles(msb), pop, wq, asc, wsc,
            bm=bm, bn=bn, bk=bk, interpret=interpret, msb_skip=msb_skip,
            acc_out=acc_out)
    else:
        out = sparqle_matmul(lsb, msb, pop, wq, asc, wsc,
                             bm=bm, bn=bn, bk=bk, interpret=interpret,
                             msb_skip=msb_skip, acc_out=acc_out)
    return out[:m, :n_out]


def sparqle_linear(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    col_mask: Optional[jax.Array] = None,
    clip_l: Optional[jax.Array] = None,
    clip_h: Optional[jax.Array] = None,
    backend: str = "pallas",
    wire_format: str = "unpacked",
    msb_skip: bool = False,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Quantize -> (clip) -> decompose -> dual-pass matmul. x: (..., K).

    ``wire_format='packed'`` streams the activation nibble planes in the
    two-per-byte wire layout (``sparqle_matmul_packed`` unpacks in-VMEM);
    bit-exact vs ``'unpacked'`` — same kernel body, half the DMA bytes.
    ``msb_skip`` runs the 1-round LSB4-only draft forward (the sparse MSB
    pass is statically elided from the kernel): the output is what you
    would get dequantizing the LSB plane alone.
    """
    from repro.core.clipping import apply_clipping

    orig = x.shape
    k_in = orig[-1]
    n_out = w.q.shape[-1]
    x2 = x.reshape(-1, k_in)

    qa = quantize_activations(x2, bits=8, per_token=True)
    q = qa.q
    if col_mask is not None and clip_l is not None:
        q = apply_clipping(q, col_mask, clip_l, clip_h)

    assert wire_format in ("unpacked", "packed"), wire_format
    if backend == "xla":
        if wire_format == "packed":
            # the wire layout, not the dense int8 tensor, feeds the matmul
            from repro.core.packing import encode_packed, unpack_planes
            act = unpack_planes(encode_packed(q))
        else:
            act = encode(q, 1.0)
        from repro.core.sparse_matmul import sparqle_matmul_xla
        msb = jnp.zeros_like(act.msb4) if msb_skip else act.msb4
        pbm = jnp.zeros_like(act.pbm) if msb_skip else act.pbm
        out = sparqle_matmul_xla(
            SparqleActivation(act.lsb4, msb, pbm, jnp.float32(1.0)),
            QuantizedTensor(w.q, jnp.ones_like(w.scale), w.zero, w.bits))
        out = out * qa.scale * w.scale.reshape(1, -1)
        return out.reshape(*orig[:-1], n_out).astype(x.dtype)

    # pallas path: pad everything to tile multiples
    out = _padded_kernel_call(q, w.q, qa.scale, w.scale,
                              wire_format=wire_format, msb_skip=msb_skip,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out.reshape(*orig[:-1], n_out).astype(x.dtype)


def sparqle_linear_sharded(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    mesh: Mesh,
    axis: str = "model",
    partition: str = "col",
    col_mask: Optional[jax.Array] = None,
    clip_l: Optional[jax.Array] = None,
    clip_h: Optional[jax.Array] = None,
    wire_format: str = "unpacked",
    msb_skip: bool = False,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """:func:`sparqle_linear` with the weight partitioned on a mesh axis.

    ``partition='col'`` shards the output channels: every shard runs the
    unsharded kernel on its (K, N/ways) slice, and the assembled output is
    the exact concatenation — bit-identical to the unsharded call.

    ``partition='row'`` shards K (activations and weight rows): the
    per-token scale comes from an exact ``pmax`` of local row maxima, the
    kernel drains its raw merged int32 accumulator (``acc_out=True`` —
    LSB and shifted-MSB partials already summed per shard), ONE ``psum``
    reduces it across the axis, and the f32 rescale runs on the reduced
    accumulator — also bit-identical, because int32 addition is
    associative. Both wire formats and the ``msb_skip`` draft dispatch
    shard identically. The replicated output is returned.
    """
    from repro.core.clipping import apply_clipping

    assert partition in ("col", "row"), partition
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    n_out = w.q.shape[-1]
    has_clip = col_mask is not None and clip_l is not None

    if partition == "col":
        def body(x_l, wq_l, wsc_l, mask):
            qa = quantize_activations(x_l, bits=8, per_token=True)
            q = qa.q
            if has_clip:
                q = apply_clipping(q, mask, clip_l, clip_h)
            return _padded_kernel_call(
                q, wq_l, qa.scale, wsc_l, wire_format=wire_format,
                msb_skip=msb_skip, bm=bm, bn=bn, bk=bk,
                interpret=interpret)

        in_specs = (P(), P(None, axis), P(None, axis),
                    P() if has_clip else None)
        out_specs = P(None, axis)
    else:
        def body(x_l, wq_l, wsc, mask):
            amax = jax.lax.pmax(
                jnp.max(jnp.abs(x_l), axis=-1, keepdims=True), axis)
            qa = quantize_activations(x_l, bits=8, per_token=True,
                                      amax=amax)
            q = qa.q
            if has_clip:
                q = apply_clipping(q, mask, clip_l, clip_h)
            acc = _padded_kernel_call(
                q, wq_l, qa.scale, wsc, wire_format=wire_format,
                msb_skip=msb_skip, bm=bm, bn=bn, bk=bk,
                interpret=interpret, acc_out=True)
            acc = jax.lax.psum(acc, axis)        # ONE reduction, int32
            return (acc.astype(jnp.float32)
                    * qa.scale.reshape(-1, 1).astype(jnp.float32)
                    * wsc.reshape(1, -1).astype(jnp.float32))

        in_specs = (P(None, axis), P(axis, None), P(),
                    P(axis) if has_clip else None)
        out_specs = P()

    fn = shard_map_compat(body, mesh, in_specs, out_specs)
    out = fn(x2, w.q.astype(jnp.int8), w.scale.reshape(1, -1),
             col_mask)
    return out.reshape(*orig[:-1], n_out).astype(x.dtype)


def dense_quant_linear(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Baseline dense W4A8 linear (no SPARQLe decomposition)."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    m = x2.shape[0]
    n_out = w.q.shape[-1]
    qa = quantize_activations(x2, bits=8, per_token=True)
    a = _pad_to(qa.q, (bm, bk))
    wq = _pad_to(w.q.astype(jnp.int8), (bk, bn))
    asc = _pad_to(qa.scale.reshape(-1, 1).astype(jnp.float32), (bm, 1))
    wsc = _pad_to(w.scale.reshape(1, -1).astype(jnp.float32), (1, bn))
    out = quant_matmul(a, wq, asc, wsc, bm=bm, bn=bn, bk=bk,
                       interpret=interpret)
    out = out[:m, :n_out]
    return out.reshape(*orig[:-1], n_out).astype(x.dtype)
