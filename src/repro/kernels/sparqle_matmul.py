"""Pallas TPU kernel: SPARQLe dual-pass matmul with PBM tile skipping.

TPU adaptation of the paper's hybrid PE array (§3.3, DESIGN.md §2):

  * the accelerator's *dense LSB4 pass* is one MXU matmul per (bm,bk,bn)
    tile over the LSB4 plane;
  * the *sparse MSB4 pass* is a second MXU matmul over the MSB4 plane,
    predicated per K-tile with ``@pl.when(tile_pop > 0)`` — the TPU-granular
    equivalent of the paper's PBM-gated operand dispatch (a 128x128 systolic
    array cannot gate individual operands, so sub-precision sparsity is
    exploited at VMEM-tile granularity; the paper's column-wise clipping is
    what clusters MSB4 zeros into skippable tiles — see
    ``clipping.importance_mask_tile_aligned``);
  * shift-by-4 accumulation into the int32 accumulator = the paper's OFRF
    accumulation of left-shifted sparse partial sums;
  * per-token activation scales and per-channel weight scales applied at
    drain time (the paper's drain-path SFU requantization).

Two operand layouts share one kernel body (``_tile_body``), so they are
bit-exact by construction:

  * :func:`sparqle_matmul` — dense nibble planes in int8 containers
    (one byte per nibble; the debug/legacy layout);
  * :func:`sparqle_matmul_packed` — nibble planes packed two-per-byte
    (``core.packing.pack_nibbles``), unpacked in-VMEM right after the DMA.
    This is the wire-format hot path: the activation blocks the grid
    streams from HBM are half the bytes of the unpacked variant.

Int4 *weights* travel in int8 containers here: ``jnp.int4`` is not fully
supported by the CPU/interpret path used for validation. On real TPU the
MXU consumes int8 natively; weight packing is handled upstream
(``qlinear.pack_int4``) and unpacked before the kernel call.

Grid: (M/bm, N/bn, K/bk), K innermost (``arbitrary``), output-stationary
accumulator scratch in VMEM. ``tile_pop`` — the per-(M-tile, K-tile) PBM
population count from ``core.sparqle.tile_population`` — is delivered as a
(1,1) block (SMEM-resident scalar on real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import unpack_nibbles
from repro.kernels import CompilerParams as _CompilerParams


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _tile_body(pop, lsb, msb_fn, w, acc_ref, *, msb_skip: bool = False):
    """Shared dual-pass accumulation for one (bm, bk, bn) tile.

    ``lsb`` is the UNPACKED (bm, bk) int8 LSB4 plane; ``msb_fn`` is a
    thunk producing the unpacked MSB4 plane — a thunk so the guarded
    branch below is what reads (and, for the packed layout, unpacks) the
    sparse plane: pop == 0 tiles skip that work entirely. Both entry
    kernels normalize their operand layout this way, which is what keeps
    the packed and unpacked paths bit-exact.

    ``msb_skip`` statically elides the sparse pass altogether: the traced
    program contains only the dense LSB4 matmul, so the result is the
    LSB4 plane's contribution alone — the 1-compute-round draft forward
    of the self-speculative decode path (vs 1 + (1 - s) rounds for the
    full hybrid pass, paper §3.3).
    """
    # ---- dense pass: LSB4 (always executes) ----
    acc_ref[...] += jax.lax.dot_general(
        lsb, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    if msb_skip:
        return

    # ---- sparse pass: MSB4, skipped when this (m,k) tile has no PBM bits
    @pl.when(pop > 0)
    def _sparse():
        acc_ref[...] += (
            jax.lax.dot_general(
                msb_fn(), w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            << 4)


def _drain(k, n_k, acc_ref, out_ref, ascale_ref, wscale_ref,
           acc_out: bool = False):
    @pl.when(k == n_k - 1)
    def _():
        if acc_out:
            # tensor-parallel drain: emit the raw merged int32 accumulator
            # (LSB + shifted MSB already summed). The caller psums it ONCE
            # across the model axis — int32 addition is associative, so
            # the reduced accumulator is bit-identical to a single-device
            # run — and applies the f32 rescale after the reduction.
            out_ref[...] = acc_ref[...]
        else:
            out_ref[...] = (
                acc_ref[...].astype(jnp.float32)
                * ascale_ref[...].astype(jnp.float32)
                * wscale_ref[...].astype(jnp.float32))


def _kernel(pop_ref, lsb_ref, msb_ref, w_ref, ascale_ref, wscale_ref,
            out_ref, acc_ref, *, n_k: int, acc_out: bool = False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _tile_body(pop_ref[0, 0], lsb_ref[...].astype(jnp.int8),
               lambda: msb_ref[...].astype(jnp.int8),
               w_ref[...].astype(jnp.int8), acc_ref)
    _drain(k, n_k, acc_ref, out_ref, ascale_ref, wscale_ref, acc_out)


def _kernel_packed(pop_ref, lsbp_ref, msbp_ref, w_ref, ascale_ref,
                   wscale_ref, out_ref, acc_ref, *, n_k: int,
                   acc_out: bool = False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # in-VMEM unpack of the half-width packed blocks (the DMA moved bk/2
    # bytes per row; the MXU still sees full (bm, bk) nibble planes) —
    # the codec's own unpack primitive, so kernel and wire layout cannot
    # drift apart; the MSB unpack happens inside the pop > 0 guard
    lsb = unpack_nibbles(lsbp_ref[...], signed=False)
    _tile_body(pop_ref[0, 0], lsb,
               lambda: unpack_nibbles(msbp_ref[...], signed=True),
               w_ref[...].astype(jnp.int8), acc_ref)
    _drain(k, n_k, acc_ref, out_ref, ascale_ref, wscale_ref, acc_out)


def _kernel_draft(lsb_ref, w_ref, ascale_ref, wscale_ref, out_ref,
                  acc_ref, *, n_k: int, acc_out: bool = False):
    """LSB4-only draft entry: the MSB plane and the PBM populations are
    not operands at all, so the grid streams HALF the (unpacked)
    activation bytes — the wire saving the cost model credits the draft
    (``costmodel.linear_cost(lsb_only=True)``), not just elided MACs."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _tile_body(0, lsb_ref[...].astype(jnp.int8), None,
               w_ref[...].astype(jnp.int8), acc_ref, msb_skip=True)
    _drain(k, n_k, acc_ref, out_ref, ascale_ref, wscale_ref, acc_out)


def _kernel_packed_draft(lsbp_ref, w_ref, ascale_ref, wscale_ref, out_ref,
                         acc_ref, *, n_k: int, acc_out: bool = False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lsb = unpack_nibbles(lsbp_ref[...], signed=False)
    _tile_body(0, lsb, None, w_ref[...].astype(jnp.int8), acc_ref,
               msb_skip=True)
    _drain(k, n_k, acc_ref, out_ref, ascale_ref, wscale_ref, acc_out)


def _call(kernel, grid, act_specs, act_args, w, act_scale, w_scale,
          tile_pop, m, n, bm, bn, bk, n_k, interpret, msb_skip=False,
          draft_kernel=None, acc_out=False):
    if msb_skip:
        # draft dispatch: ONLY the LSB plane is an operand — the MSB
        # plane and PBM populations never enter the grid's DMA stream
        kernel, in_specs = draft_kernel, [act_specs[0]]
        args = (act_args[0], w, act_scale, w_scale)
    else:
        in_specs = [
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk)),        # tile_pop
            *act_specs,                                            # lsb, msb
        ]
        args = (tile_pop, *act_args, w, act_scale, w_scale)
    return pl.pallas_call(
        functools.partial(kernel, n_k=n_k, acc_out=acc_out),
        grid=grid,
        in_specs=[
            *in_specs,
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),      # w
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),        # act_scale
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),        # w_scale
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (m, n), jnp.int32 if acc_out else jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "msb_skip", "acc_out"))
def sparqle_matmul(
    lsb4: jax.Array,       # (M, K) int8 in [0, 15]
    msb4: jax.Array,       # (M, K) int8 in [-8, 7]
    tile_pop: jax.Array,   # (M/bm, K/bk) int32 PBM population per tile
    w: jax.Array,          # (K, N) int8 (int4 payload)
    act_scale: jax.Array,  # (M, 1) f32
    w_scale: jax.Array,    # (1, N) f32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
    msb_skip: bool = False,
    acc_out: bool = False,
) -> jax.Array:
    """``acc_out`` emits the raw merged int32 accumulator instead of the
    rescaled f32 output (scale operands are ignored) — the operand a
    K-sharded tensor-parallel caller reduces with a single psum before
    applying the drain-path rescale (``ops.sparqle_linear_sharded``)."""
    m, k = lsb4.shape
    k2, n = w.shape
    assert k == k2, (lsb4.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"operands must be tile-aligned: {(m, k, n)} vs {(bm, bk, bn)}")
    assert tile_pop.shape == (m // bm, k // bk), tile_pop.shape

    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    act_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),      # lsb4
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),      # msb4
    ]
    return _call(_kernel, grid, act_specs, (lsb4, msb4), w, act_scale,
                 w_scale, tile_pop, m, n, bm, bn, bk, n_k, interpret,
                 msb_skip=msb_skip, draft_kernel=_kernel_draft,
                 acc_out=acc_out)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "msb_skip", "acc_out"))
def sparqle_matmul_packed(
    lsb4_packed: jax.Array,  # (M, K/2) int8 — two LSB nibbles per byte
    msb4_packed: jax.Array,  # (M, K/2) int8 — two MSB nibbles per byte
    tile_pop: jax.Array,     # (M/bm, K/bk) int32 PBM population per tile
    w: jax.Array,            # (K, N) int8 (int4 payload)
    act_scale: jax.Array,    # (M, 1) f32
    w_scale: jax.Array,      # (1, N) f32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
    msb_skip: bool = False,
    acc_out: bool = False,
) -> jax.Array:
    """Wire-format variant of :func:`sparqle_matmul`.

    Activation planes arrive packed two-per-byte (half the DMA bytes) and
    are unpacked in VMEM; the accumulation body is shared, so outputs are
    bit-exact vs the unpacked kernel on identical logical operands.

    ``msb_skip`` dispatches the LSB4-only draft kernel: the ``msb4`` /
    ``tile_pop`` arguments are accepted for signature parity but are NOT
    operands of the pallas_call — the draft grid streams only the LSB
    plane plus weights/scales. ``acc_out`` as in :func:`sparqle_matmul`.
    """
    m, kh = lsb4_packed.shape
    k = kh * 2
    k2, n = w.shape
    assert k == k2, (lsb4_packed.shape, w.shape)
    assert msb4_packed.shape == (m, kh), msb4_packed.shape
    assert bk % 2 == 0, bk
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"operands must be tile-aligned: {(m, k, n)} vs {(bm, bk, bn)}")
    assert tile_pop.shape == (m // bm, k // bk), tile_pop.shape

    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    hbk = bk // 2
    act_specs = [
        pl.BlockSpec((bm, hbk), lambda i, j, kk: (i, kk)),     # lsb4 packed
        pl.BlockSpec((bm, hbk), lambda i, j, kk: (i, kk)),     # msb4 packed
    ]
    return _call(_kernel_packed, grid, act_specs,
                 (lsb4_packed, msb4_packed), w, act_scale, w_scale,
                 tile_pop, m, n, bm, bn, bk, n_k, interpret,
                 msb_skip=msb_skip, draft_kernel=_kernel_packed_draft,
                 acc_out=acc_out)
