"""Pallas TPU kernel: SPARQLe dual-pass matmul with PBM tile skipping.

TPU adaptation of the paper's hybrid PE array (§3.3, DESIGN.md §2):

  * the accelerator's *dense LSB4 pass* is one MXU matmul per (bm,bk,bn)
    tile over the LSB4 plane;
  * the *sparse MSB4 pass* is a second MXU matmul over the MSB4 plane,
    predicated per K-tile with ``@pl.when(tile_pop > 0)`` — the TPU-granular
    equivalent of the paper's PBM-gated operand dispatch (a 128x128 systolic
    array cannot gate individual operands, so sub-precision sparsity is
    exploited at VMEM-tile granularity; the paper's column-wise clipping is
    what clusters MSB4 zeros into skippable tiles — see
    ``clipping.importance_mask_tile_aligned``);
  * shift-by-4 accumulation into the int32 accumulator = the paper's OFRF
    accumulation of left-shifted sparse partial sums;
  * per-token activation scales and per-channel weight scales applied at
    drain time (the paper's drain-path SFU requantization).

4-bit payloads (LSB4/MSB4 in [0,15]/[-8,7], int4 weights) travel in int8
containers: ``jnp.int4`` is not fully supported by the CPU/interpret path
used for validation. On real TPU the MXU consumes int8 natively; true int4
packing halves DMA bytes and is accounted analytically in the roofline and
the cost model.

Grid: (M/bm, N/bn, K/bk), K innermost (``arbitrary``), output-stationary
accumulator scratch in VMEM. ``tile_pop`` — the per-(M-tile, K-tile) PBM
population count from ``core.sparqle.tile_population`` — is delivered as a
(1,1) block (SMEM-resident scalar on real TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel(pop_ref, lsb_ref, msb_ref, w_ref, ascale_ref, wscale_ref,
            out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.int8)

    # ---- dense pass: LSB4 (always executes) ----
    lsb = lsb_ref[...].astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        lsb, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    # ---- sparse pass: MSB4, skipped when this (m,k) tile has no PBM bits ----
    pop = pop_ref[0, 0]

    @pl.when(pop > 0)
    def _sparse():
        msb = msb_ref[...].astype(jnp.int8)
        acc_ref[...] += (
            jax.lax.dot_general(
                msb, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            << 4)

    # ---- drain: requantize with act/weight scales ----
    @pl.when(k == n_k - 1)
    def _drain():
        out_ref[...] = (
            acc_ref[...].astype(jnp.float32)
            * ascale_ref[...].astype(jnp.float32)
            * wscale_ref[...].astype(jnp.float32))


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def sparqle_matmul(
    lsb4: jax.Array,       # (M, K) int8 in [0, 15]
    msb4: jax.Array,       # (M, K) int8 in [-8, 7]
    tile_pop: jax.Array,   # (M/bm, K/bk) int32 PBM population per tile
    w: jax.Array,          # (K, N) int8 (int4 payload)
    act_scale: jax.Array,  # (M, 1) f32
    w_scale: jax.Array,    # (1, N) f32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    m, k = lsb4.shape
    k2, n = w.shape
    assert k == k2, (lsb4.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"operands must be tile-aligned: {(m, k, n)} vs {(bm, bk, bn)}")
    assert tile_pop.shape == (m // bm, k // bk), tile_pop.shape

    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk)),        # tile_pop
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),      # lsb4
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),      # msb4
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),      # w
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),        # act_scale
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),        # w_scale
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(tile_pop, lsb4, msb4, w, act_scale, w_scale)
