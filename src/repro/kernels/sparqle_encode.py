"""Pallas TPU kernel: fused drain-path SPARQLe encoder.

The paper's drain phase (§3.3) writes linear-layer outputs back to SRAM
*already in SPARQLe format* (MSB4/LSB4 splitters + sparse encoder beyond the
drain buffer). The TPU-side analogue fuses output quantization with the
LSB4/MSB4/PBM decomposition in one elementwise VPU kernel, so the next layer
reads decomposed planes without a decompress-compute-recompress round trip.

Outputs the per-(bm, bk) tile PBM population counts as well — the metadata
the matmul kernel's ``@pl.when`` skipping consumes.

Two emit layouts:

  * :func:`sparqle_encode` — dense int8 nibble planes (debug/legacy);
  * :func:`sparqle_encode_packed` — the wire-format planes the packed
    matmul consumes: LSB4/MSB4 packed two nibbles per byte and the PBM
    folded into uint32 bitmask words (``core/packing.py`` layout), so the
    drain stream is the compressed format, not dense int8.

Per-token scales are clamped away from zero/denormal before the divide:
an all-zero token (padded prefill rows writing through the null page)
produces ``scale == 0`` and ``x / 0`` would round inf/nan into ±127
garbage; the clamp makes such rows encode exactly to zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import PBM_WORD_BITS, pack_nibbles, pack_pbm

# Smallest normal f32: anything below (zero or denormal scales) is treated
# as a degenerate all-zero token and divided by 1 instead.
_MIN_SCALE = float(jnp.finfo(jnp.float32).tiny)


def _quantize(x_ref, scale_ref):
    s = scale_ref[...].astype(jnp.float32)
    s = jnp.where(jnp.abs(s) < _MIN_SCALE, 1.0, s)
    x = x_ref[...].astype(jnp.float32) / s
    q = jnp.clip(jnp.round(x), -128, 127).astype(jnp.int8)
    msb = jnp.right_shift(q, 4)
    lsb = jnp.bitwise_and(q, 0xF)
    return lsb, msb, msb != 0


def _kernel(x_ref, scale_ref, lsb_ref, msb_ref, pbm_ref, pop_ref):
    lsb, msb, pbm = _quantize(x_ref, scale_ref)
    lsb_ref[...] = lsb.astype(jnp.int8)
    msb_ref[...] = msb.astype(jnp.int8)
    pbm_ref[...] = pbm
    pop_ref[0, 0] = jnp.sum(pbm.astype(jnp.int32))


def _kernel_packed(x_ref, scale_ref, lsb_ref, msb_ref, pbm_ref, pop_ref):
    # emit through the codec's own primitives, so the drain stream and
    # the core/packing.py wire layout cannot drift apart
    lsb, msb, pbm = _quantize(x_ref, scale_ref)
    lsb_ref[...] = pack_nibbles(lsb)
    msb_ref[...] = pack_nibbles(msb)
    pbm_ref[...] = pack_pbm(pbm)
    pop_ref[0, 0] = jnp.sum(pbm.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def sparqle_encode(
    x: jax.Array,       # (M, K) f32/bf16 pre-quantization outputs
    scale: jax.Array,   # (M, 1) f32 per-token scales
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    """Returns (lsb4, msb4, pbm, tile_pop) with tile_pop (M/bm, K/bk)."""
    m, k = x.shape
    assert m % bm == 0 and k % bk == 0, (x.shape, bm, bk)
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, k), jnp.bool_),
            jax.ShapeDtypeStruct((m // bm, k // bk), jnp.int32),
        ],
        interpret=interpret,
    )(x, scale)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def sparqle_encode_packed(
    x: jax.Array,       # (M, K) f32/bf16 pre-quantization outputs
    scale: jax.Array,   # (M, 1) f32 per-token scales
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    """Wire-format drain: (lsb4_packed (M, K/2), msb4_packed (M, K/2),
    pbm_words (M, K/32), tile_pop (M/bm, K/bk)).

    ``bk`` must be a multiple of 32 so PBM words never straddle tiles.
    Bit-exact with ``core.packing`` on the quantized values: unpacking the
    emitted planes reproduces ``sparqle_encode``'s planes exactly.
    """
    m, k = x.shape
    assert m % bm == 0 and k % bk == 0, (x.shape, bm, bk)
    assert bk % PBM_WORD_BITS == 0, bk
    grid = (m // bm, k // bk)
    hbk = bk // 2
    nw = bk // PBM_WORD_BITS
    return pl.pallas_call(
        _kernel_packed,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, hbk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, hbk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, nw), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k // 2), jnp.int8),
            jax.ShapeDtypeStruct((m, k // 2), jnp.int8),
            jax.ShapeDtypeStruct((m, k // PBM_WORD_BITS), jnp.uint32),
            jax.ShapeDtypeStruct((m // bm, k // bk), jnp.int32),
        ],
        interpret=interpret,
    )(x, scale)
