"""Pallas TPU kernel: fused drain-path SPARQLe encoder.

The paper's drain phase (§3.3) writes linear-layer outputs back to SRAM
*already in SPARQLe format* (MSB4/LSB4 splitters + sparse encoder beyond the
drain buffer). The TPU-side analogue fuses output quantization with the
LSB4/MSB4/PBM decomposition in one elementwise VPU kernel, so the next layer
reads decomposed planes without a decompress-compute-recompress round trip.

Outputs the per-(bm, bk) tile PBM population counts as well — the metadata
the matmul kernel's ``@pl.when`` skipping consumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, lsb_ref, msb_ref, pbm_ref, pop_ref):
    x = x_ref[...].astype(jnp.float32) / scale_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x), -128, 127).astype(jnp.int8)
    msb = jnp.right_shift(q, 4)
    lsb = jnp.bitwise_and(q, 0xF)
    pbm = msb != 0
    lsb_ref[...] = lsb.astype(jnp.int8)
    msb_ref[...] = msb.astype(jnp.int8)
    pbm_ref[...] = pbm
    pop_ref[0, 0] = jnp.sum(pbm.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def sparqle_encode(
    x: jax.Array,       # (M, K) f32/bf16 pre-quantization outputs
    scale: jax.Array,   # (M, 1) f32 per-token scales
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    """Returns (lsb4, msb4, pbm, tile_pop) with tile_pop (M/bm, K/bk)."""
    m, k = x.shape
    assert m % bm == 0 and k % bk == 0, (x.shape, bm, bk)
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, k), jnp.bool_),
            jax.ShapeDtypeStruct((m // bm, k // bk), jnp.int32),
        ],
        interpret=interpret,
    )(x, scale)
