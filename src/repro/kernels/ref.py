"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical contracts: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function of the same name here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparqle_matmul_ref(
    lsb4: jax.Array,      # (M, K) int8, values in [0, 15]
    msb4: jax.Array,      # (M, K) int8, values in [-8, 7]
    w: jax.Array,         # (K, N) int8, int4 payload in [-8, 7]
    act_scale: jax.Array,  # (M, 1) f32
    w_scale: jax.Array,    # (1, N) f32
) -> jax.Array:
    """Dual-pass W4A8 matmul: out = ((lsb + 16*msb) @ w) * scales."""
    dense = jax.lax.dot_general(
        lsb4.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())))
    sparse = jax.lax.dot_general(
        msb4.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())))
    acc = dense + 16 * sparse
    return acc.astype(jnp.float32) * act_scale * w_scale


def quant_matmul_ref(
    a: jax.Array,          # (M, K) int8 activations
    w: jax.Array,          # (K, N) int8 (int4 payload)
    act_scale: jax.Array,  # (M, 1) f32
    w_scale: jax.Array,    # (1, N) f32
) -> jax.Array:
    """Dense int8 x int4 matmul (the paper's baseline accelerator)."""
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), w.astype(jnp.int32), (((1,), (0,)), ((), ())))
    return acc.astype(jnp.float32) * act_scale * w_scale


def sparqle_encode_ref(x_int8: jax.Array):
    """Drain-path encoder: int8 -> (lsb4, msb4, pbm)."""
    x = x_int8.astype(jnp.int8)
    msb = jnp.right_shift(x, 4)
    lsb = jnp.bitwise_and(x, 0xF)
    return lsb.astype(jnp.int8), msb.astype(jnp.int8), msb != 0


def kv4_decode_attention_ref(q, k_q, k_s, v_q, v_s, pos):
    """Decode attention over a packed-int4 KV cache (dense reference).

    q (B,KVH,G,hd); k_q/v_q (B,S,KVH,hd//2) packed nibbles; scales
    (B,S,KVH); pos (B,). Returns (B,KVH,G,hd) f32-computed output.
    """
    def unpack(p):
        lo = jnp.right_shift(jnp.left_shift(p, 4), 4)
        hi = jnp.right_shift(p, 4)
        return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1],
                                                    p.shape[-1] * 2)

    k = unpack(k_q).astype(jnp.float32) * k_s[..., None]
    v = unpack(v_q).astype(jnp.float32) * v_s[..., None]
    hd = q.shape[-1]
    s = jnp.einsum("bhgd,bjhd->bhgj", q.astype(jnp.float32), k)
    s = s * hd ** -0.5
    smax = k.shape[1]
    allow = jnp.arange(smax)[None, :] <= pos[:, None]
    s = jnp.where(allow[:, None, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgj,bjhd->bhgd", p, v)
    return out.astype(q.dtype)
