"""Pallas TPU kernels for the SPARQLe compute hot-spots.

  * sparqle_matmul   — dual-pass (LSB4 dense + PBM-gated MSB4) W4A8 matmul
  * quant_matmul     — dense int8 x int4 baseline (the paper's baseline
                       accelerator, iso-tiling)
  * sparqle_encode   — fused drain-path output quantize + decompose
  * kv_attention     — decode attention with in-VMEM unpack/dequant of the
                       packed-int4 KV cache (flash-decoding structure);
                       contiguous and paged (block-table) variants share
                       one kernel body

Each kernel ships with a pure-jnp oracle in ref.py and interpret-mode
allclose sweeps in tests/test_kernels.py; ops.py holds the jit'd public
wrappers (padding, backend dispatch).
"""
from jax.experimental.pallas import tpu as _pltpu

# jax < 0.6 names this TPUCompilerParams; one shim shared by all kernels
CompilerParams = (getattr(_pltpu, "CompilerParams", None)
                  or _pltpu.TPUCompilerParams)
