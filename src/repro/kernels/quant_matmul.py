"""Pallas TPU kernel: dense int8 x int4 matmul — the paper's baseline.

This is the iso-MAC *dense accelerator baseline* of paper §4 (Table 1): a
standard W4A8 matmul with no sub-precision decomposition. It exists so the
benchmark harness can compare SPARQLe vs baseline at the kernel level with
identical tiling, and so the serving path has a non-SPARQLe quantized mode.

Same tiling/accumulation structure as ``sparqle_matmul`` (one int8 pass
instead of two int4 passes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

from repro.kernels.sparqle_matmul import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN


def _kernel(a_ref, w_ref, ascale_ref, wscale_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.int8), w_ref[...].astype(jnp.int8),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _drain():
        out_ref[...] = (
            acc_ref[...].astype(jnp.float32)
            * ascale_ref[...].astype(jnp.float32)
            * wscale_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(
    a: jax.Array,          # (M, K) int8 activations
    w: jax.Array,          # (K, N) int8 (int4 payload)
    act_scale: jax.Array,  # (M, 1) f32
    w_scale: jax.Array,    # (1, N) f32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    m, k = a.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0

    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, w, act_scale, w_scale)
