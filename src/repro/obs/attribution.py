"""Per-step performance attribution: compiled-HLO cost x measured time.

Closes the loop between what a serving step *is* (the optimized HLO the
engine actually executes) and what it *does* at runtime (measured wall
time per phase). At warm-up — ``Engine.attribute_steps()`` — each jitted
serving step (prefill_chunk / decode, plus draft / verify on the
speculative engine) is lowered and compiled a second time against
abstract avals of its real arguments, the optimized HLO is walked by
``launch/hlo_analysis.py`` in strict mode (unknown dtypes or unparsed
ops are a hard error, never an undercount), and the per-step FLOPs, HBM
bytes and per-kind collective bytes land in the metrics registry:

  * ``serving_step_attr_flops{phase=}``        — dot FLOPs per engine
    step (per device shard; draft scaled by its γ calls per step),
  * ``serving_step_attr_hbm_bytes{phase=}``    — op-level HBM proxy,
  * ``serving_step_attr_coll_bytes{phase=,kind=}`` — collective payload,
  * ``serving_step_attr_tokens{phase=}``       — tokens one step moves,
  * ``serving_attr_compile_seconds{phase=}``   — attribution AOT
    compile cost (so warm-up regressions are visible).

At read time (``Engine._refresh_gauges``) the static costs join the
measured ``serving_step_seconds`` means into roofline-style utilization
against ``costmodel.HardwareConfig`` system peaks:

  * ``serving_roofline_achieved_flops_per_s{phase=}`` and
    ``serving_roofline_achieved_bytes_per_s{phase=}``,
  * ``serving_roofline_compute_util_ratio{phase=}`` /
    ``serving_roofline_memory_util_ratio{phase=}``.

and into **cost-model drift** — measurement vs prediction:

  * ``serving_costmodel_wire_drift_ratio`` — measured wire bytes/token
    over the Eq. 1 prediction at the measured per-layer sparsity
    (dimensionless, ~1.0 when the codec matches the paper's format),
  * ``serving_costmodel_latency_drift_ratio{phase=}`` — measured step
    seconds over ``costmodel.phase_cost`` predicted seconds (on CPU
    interpret the absolute value is meaningless; the *trajectory* is the
    signal, so drift instants fire on change vs the first observation),
  * ``serving_costmodel_drift_events_total{phase=}`` — edge-triggered
    out-of-band events, each also dropped as a ``costmodel_drift``
    instant on the tracer's engine track.

Everything here is host-side (SPL002: ``obs/`` is a host-only module) —
lowering/compiling via ``fn.lower(...)`` inspects programs but never
executes device code, and no ``jnp``/``lax`` op appears in this module.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.launch import hlo_analysis

# latency drift is judged against the FIRST measured/predicted ratio
# (CPU-interpret absolute ratios are meaningless; change is the signal);
# wire drift is judged against 1.0 (Eq. 1 should match measurement)
DEFAULT_LATENCY_DRIFT_FACTOR = 2.0
DEFAULT_WIRE_DRIFT_TOL = 0.15

# attribution compile times land in seconds-scale buckets
_COMPILE_BUCKETS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
                    30.0, 60.0, 120.0)


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Static cost of ONE engine step of a phase (per device shard)."""

    phase: str
    flops: float                 # dot FLOPs per engine step
    hbm_bytes: float             # op-level operand+result byte proxy
    coll_bytes: Dict[str, float]  # per collective kind (+"total")
    tokens_per_step: int         # tokens one engine step moves
    calls_per_step: int = 1      # jitted calls per timed phase (draft: γ)
    compile_seconds: float = 0.0

    @property
    def flops_per_token(self) -> float:
        return self.flops / max(self.tokens_per_step, 1)

    @property
    def hbm_bytes_per_token(self) -> float:
        return self.hbm_bytes / max(self.tokens_per_step, 1)


class _PhaseState:
    __slots__ = ("cost", "predict_seconds", "ref_latency_ratio",
                 "out_of_band")

    def __init__(self, cost: StepCost,
                 predict_seconds: Optional[Callable[[float], float]]):
        self.cost = cost
        self.predict_seconds = predict_seconds
        self.ref_latency_ratio: Optional[float] = None
        self.out_of_band = False


class StepAttribution:
    """Owns the attribution metrics and the static per-phase costs.

    One instance per engine (created lazily by ``attribute_steps``); the
    registry's create-or-get makes re-registration across engines
    sharing an ``Observability`` safe.
    """

    def __init__(self, obs, hw=None,
                 latency_drift_factor: float = DEFAULT_LATENCY_DRIFT_FACTOR,
                 wire_drift_tol: float = DEFAULT_WIRE_DRIFT_TOL):
        from repro.core.costmodel import HardwareConfig
        self.obs = obs
        self.hw = hw or HardwareConfig()
        self.latency_drift_factor = float(latency_drift_factor)
        self.wire_drift_tol = float(wire_drift_tol)
        self._phases: Dict[str, _PhaseState] = {}
        self._wire_out_of_band = False
        r = obs.registry
        self._g_flops = r.gauge(
            "serving_step_attr_flops", "dot FLOPs one engine step of "
            "this phase executes (compiled HLO, per device shard)",
            unit="flops", labelnames=("phase",))
        self._g_hbm = r.gauge(
            "serving_step_attr_hbm_bytes", "operand+result bytes of "
            "top-level HLO ops per engine step (HBM traffic proxy, per "
            "device shard)", unit="bytes", labelnames=("phase",))
        self._g_coll = r.gauge(
            "serving_step_attr_coll_bytes", "collective payload bytes "
            "per engine step, by kind", unit="bytes",
            labelnames=("phase", "kind"))
        self._g_tokens = r.gauge(
            "serving_step_attr_tokens", "tokens one engine step of this "
            "phase moves", unit="tokens", labelnames=("phase",))
        self._h_compile = r.histogram(
            "serving_attr_compile_seconds", "attribution-time AOT "
            "lower+compile cost per phase", unit="seconds",
            labelnames=("phase",), buckets=_COMPILE_BUCKETS)
        self._g_flops_s = r.gauge(
            "serving_roofline_achieved_flops_per_s", "attributed FLOPs "
            "over measured mean step wall time", unit="per_second",
            labelnames=("phase",))
        self._g_bytes_s = r.gauge(
            "serving_roofline_achieved_bytes_per_s", "attributed HBM "
            "bytes over measured mean step wall time", unit="per_second",
            labelnames=("phase",))
        self._g_cutil = r.gauge(
            "serving_roofline_compute_util_ratio", "achieved FLOP/s over "
            "HardwareConfig.peak_flops", unit="ratio",
            labelnames=("phase",))
        self._g_mutil = r.gauge(
            "serving_roofline_memory_util_ratio", "achieved HBM bytes/s "
            "over HardwareConfig.hbm_bw", unit="ratio",
            labelnames=("phase",))
        self._g_lat_drift = r.gauge(
            "serving_costmodel_latency_drift_ratio", "measured step "
            "seconds / costmodel.phase_cost predicted seconds",
            unit="ratio", labelnames=("phase",))
        self._g_wire_drift = r.gauge(
            "serving_costmodel_wire_drift_ratio", "measured wire "
            "bytes/token / Eq.1 prediction at measured sparsity",
            unit="ratio")
        self._c_drift = r.counter(
            "serving_costmodel_drift_events_total", "edge-triggered "
            "out-of-band cost-model drift events (phase label 'wire' "
            "for wire-byte drift)", unit="events", labelnames=("phase",))

    # -- static attribution ------------------------------------------------

    def attribute(self, phase: str, fn, args, *, tokens_per_step: int,
                  calls_per_step: int = 1,
                  predict_seconds: Optional[Callable[[float], float]] = None,
                  strict: bool = True) -> StepCost:
        """Lower+compile one jitted step fn and register its HLO cost.

        ``args`` are abstract avals (``launch.steps.abstract_like`` of
        the runtime arguments) — lowering never touches live (donated)
        buffers. Idempotent per phase: a second call for an
        already-attributed phase returns the cached cost.
        """
        if phase in self._phases:
            return self._phases[phase].cost
        clock = self.obs.registry.clock
        t0 = clock()
        compiled = fn.lower(*args).compile()
        dt = clock() - t0
        stats = hlo_analysis.analyze(compiled.as_text(), strict=strict)
        coll = {k: v * calls_per_step
                for k, v in stats.coll_bytes.items()}
        cost = StepCost(
            phase=phase,
            flops=stats.flops * calls_per_step,
            hbm_bytes=stats.hbm_bytes * calls_per_step,
            coll_bytes=coll,
            tokens_per_step=tokens_per_step,
            calls_per_step=calls_per_step,
            compile_seconds=dt)
        self._h_compile.observe(dt, phase=phase)
        self.register_cost(cost, predict_seconds=predict_seconds)
        return cost

    def register_cost(self, cost: StepCost, *,
                      predict_seconds: Optional[Callable[[float], float]]
                      = None) -> None:
        """Install a static cost (the seam ``attribute`` uses; tests
        inject synthetic costs here to pin the drift math)."""
        self._phases[cost.phase] = _PhaseState(cost, predict_seconds)
        self._g_flops.set(cost.flops, phase=cost.phase)
        self._g_hbm.set(cost.hbm_bytes, phase=cost.phase)
        self._g_tokens.set(cost.tokens_per_step, phase=cost.phase)
        for kind, b in cost.coll_bytes.items():
            self._g_coll.set(b, phase=cost.phase, kind=kind)

    def phases(self) -> List[str]:
        return list(self._phases)

    def cost(self, phase: str) -> Optional[StepCost]:
        st = self._phases.get(phase)
        return st.cost if st else None

    # -- runtime join ------------------------------------------------------

    def observe_runtime(self, phase: str, mean_step_seconds: float,
                        sparsity: float = 0.0) -> None:
        """Join one phase's measured mean step time with its static cost.

        Sets the roofline gauges and, when the phase has a latency
        predictor, the cost-model latency drift ratio. The first
        observation pins the reference ratio; later observations outside
        ``[ref/factor, ref*factor]`` fire an edge-triggered drift event.
        """
        st = self._phases.get(phase)
        if st is None or mean_step_seconds <= 0.0:
            return
        cost = st.cost
        flops_s = cost.flops / mean_step_seconds
        bytes_s = cost.hbm_bytes / mean_step_seconds
        self._g_flops_s.set(flops_s, phase=phase)
        self._g_bytes_s.set(bytes_s, phase=phase)
        self._g_cutil.set(flops_s / self.hw.peak_flops, phase=phase)
        self._g_mutil.set(bytes_s / self.hw.hbm_bw, phase=phase)
        if st.predict_seconds is None:
            return
        predicted = st.predict_seconds(sparsity)
        if predicted <= 0.0:
            return
        ratio = mean_step_seconds / predicted
        self._g_lat_drift.set(ratio, phase=phase)
        if st.ref_latency_ratio is None:
            st.ref_latency_ratio = ratio
            return
        f = self.latency_drift_factor
        out = not (st.ref_latency_ratio / f <= ratio
                   <= st.ref_latency_ratio * f)
        if out and not st.out_of_band:
            self._c_drift.inc(phase=phase)
            self.obs.tracer.instant(
                "costmodel_drift", kind="latency", phase=phase,
                ratio=ratio, reference=st.ref_latency_ratio)
        st.out_of_band = out

    def observe_wire(self, measured_bytes_per_token: float,
                     predicted_bytes_per_token: float) -> None:
        """Judge measured wire bytes/token against the Eq. 1 prediction.

        The ratio should sit at ~1.0 (PR 3 pinned the codec to within
        0.2% of Eq. 1); outside ``1 ± wire_drift_tol`` an edge-triggered
        drift event fires with phase label ``wire``.
        """
        if predicted_bytes_per_token <= 0.0:
            return
        ratio = measured_bytes_per_token / predicted_bytes_per_token
        self._g_wire_drift.set(ratio)
        out = abs(ratio - 1.0) > self.wire_drift_tol
        if out and not self._wire_out_of_band:
            self._c_drift.inc(phase="wire")
            self.obs.tracer.instant(
                "costmodel_drift", kind="wire", phase="wire",
                ratio=ratio, tolerance=self.wire_drift_tol)
        self._wire_out_of_band = out

    # -- export ------------------------------------------------------------

    def summary(self) -> Dict[str, Dict[str, float]]:
        """JSON-ready per-phase static costs (what the bench stamps into
        its result and perf_history records)."""
        out: Dict[str, Dict[str, float]] = {}
        for phase, st in self._phases.items():
            c = st.cost
            out[phase] = {
                "flops": c.flops, "hbm_bytes": c.hbm_bytes,
                "coll_bytes_total": c.coll_bytes.get("total", 0.0),
                "tokens_per_step": float(c.tokens_per_step),
                "calls_per_step": float(c.calls_per_step),
                "flops_per_token": c.flops_per_token,
                "hbm_bytes_per_token": c.hbm_bytes_per_token,
                "compile_seconds": c.compile_seconds,
            }
        return out
