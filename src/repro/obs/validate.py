"""Schema validators for observability artifacts.

Shared by the unit tests and ``benchmarks/check_metrics_schema.py`` (the
CI check): one source of truth for what a valid registry snapshot and a
valid (Perfetto-loadable) Chrome trace look like. Each validator returns
a list of human-readable problems — empty means valid.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.obs.metrics import METRIC_NAME_RE

_KINDS = ("counter", "gauge", "histogram")
_PHASES = ("B", "E", "X", "i", "I", "M", "C")


def validate_snapshot(snap: Dict) -> List[str]:
    """Problems in a ``MetricsRegistry.snapshot()`` dict."""
    problems: List[str] = []
    if not isinstance(snap, dict):
        return [f"snapshot must be a dict, got {type(snap).__name__}"]
    for name, entry in snap.items():
        where = f"metric {name!r}"
        if not METRIC_NAME_RE.match(str(name)):
            problems.append(f"{where}: name must match "
                            f"{METRIC_NAME_RE.pattern}")
        if not isinstance(entry, dict):
            problems.append(f"{where}: entry must be a dict")
            continue
        kind = entry.get("type")
        if kind not in _KINDS:
            problems.append(f"{where}: type {kind!r} not in {_KINDS}")
        if not entry.get("unit"):
            problems.append(f"{where}: missing declared unit")
        series = entry.get("series")
        if not isinstance(series, list):
            problems.append(f"{where}: series must be a list")
            continue
        for i, s in enumerate(series):
            sw = f"{where} series[{i}]"
            if not isinstance(s.get("labels"), dict):
                problems.append(f"{sw}: missing labels dict")
                continue
            for ln in s["labels"]:
                if not METRIC_NAME_RE.match(str(ln)):
                    problems.append(f"{sw}: bad label name {ln!r}")
            if kind == "histogram":
                buckets = entry.get("buckets")
                if (not isinstance(buckets, list) or not buckets
                        or buckets != sorted(buckets)):
                    problems.append(f"{where}: histogram needs ascending "
                                    f"buckets")
                    continue
                counts = s.get("bucket_counts")
                if (not isinstance(counts, list)
                        or len(counts) != len(buckets) + 1):
                    problems.append(f"{sw}: bucket_counts must have "
                                    f"len(buckets)+1 entries")
                elif sum(counts) != s.get("count"):
                    problems.append(f"{sw}: bucket_counts sum "
                                    f"{sum(counts)} != count "
                                    f"{s.get('count')}")
                if not isinstance(s.get("sum"), (int, float)):
                    problems.append(f"{sw}: missing sum")
                for p in ("p50", "p90", "p99"):
                    if p not in s:
                        problems.append(f"{sw}: missing {p}")
            else:
                v = s.get("value")
                if not isinstance(v, (int, float)):
                    problems.append(f"{sw}: missing scalar value")
    return problems


def _label_set(snap: Dict, name: str, label: str) -> set:
    entry = snap.get(name) or {}
    return {s.get("labels", {}).get(label)
            for s in entry.get("series", [])}


def _series_values(snap: Dict, name: str):
    entry = snap.get(name) or {}
    for s in entry.get("series", []):
        if "value" in s:
            yield s.get("labels", {}), s["value"]


ATTRIBUTION_METRICS = ("serving_step_attr_flops",
                       "serving_step_attr_hbm_bytes",
                       "serving_step_attr_tokens",
                       "serving_attr_compile_seconds")
SLO_METRICS = ("serving_slo_value", "serving_slo_target",
               "serving_slo_compliant", "serving_slo_burn_rate")


def validate_attribution(snap: Dict, require: bool = False) -> List[str]:
    """Family-level contract for the attribution / roofline / drift /
    SLO metrics inside one registry snapshot.

    Present-family consistency is always checked (same phase set across
    the ``serving_step_attr_*`` gauges, non-negative finite values,
    SLO compliance gauges boolean, targets present for every SLO).
    ``require=True`` additionally fails when the attribution family is
    absent entirely — the CI bench gate passes this so a silently
    un-attributed engine cannot sail through the schema check.
    """
    problems: List[str] = []
    if not isinstance(snap, dict):
        return ["snapshot must be a dict"]
    has_attr = "serving_step_attr_flops" in snap
    if require and not has_attr:
        problems.append("attribution family missing: no "
                        "serving_step_attr_flops in snapshot (engine "
                        "never ran attribute_steps?)")
    if has_attr:
        for name in ATTRIBUTION_METRICS:
            if name not in snap:
                problems.append(f"attribution family incomplete: "
                                f"{name} missing")
        phases = _label_set(snap, "serving_step_attr_flops", "phase")
        if not phases:
            problems.append("serving_step_attr_flops has no series")
        for name in ("serving_step_attr_hbm_bytes",
                     "serving_step_attr_tokens"):
            got = _label_set(snap, name, "phase")
            if name in snap and got != phases:
                problems.append(f"{name}: phase set {sorted(map(str, got))} "
                                f"!= attr flops phases "
                                f"{sorted(map(str, phases))}")
        for name in ("serving_step_attr_flops",
                     "serving_step_attr_hbm_bytes",
                     "serving_step_attr_tokens",
                     "serving_step_attr_coll_bytes"):
            for labels, v in _series_values(snap, name):
                if not (isinstance(v, (int, float)) and math.isfinite(v)
                        and v >= 0):
                    problems.append(f"{name}{labels}: bad value {v!r}")
        for name in ("serving_roofline_compute_util_ratio",
                     "serving_roofline_memory_util_ratio"):
            for labels, v in _series_values(snap, name):
                if not (isinstance(v, (int, float)) and math.isfinite(v)
                        and v >= 0):
                    problems.append(f"{name}{labels}: utilization must "
                                    f"be finite and >= 0, got {v!r}")
                if labels.get("phase") not in phases:
                    problems.append(f"{name}{labels}: phase not "
                                    f"attributed")
        for labels, v in _series_values(
                snap, "serving_costmodel_wire_drift_ratio"):
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v > 0):
                problems.append(f"serving_costmodel_wire_drift_ratio"
                                f"{labels}: ratio must be finite and "
                                f"> 0, got {v!r}")
    if "serving_slo_value" in snap:
        for name in ("serving_slo_target", "serving_slo_compliant"):
            if name not in snap:
                problems.append(f"SLO family incomplete: {name} missing")
        slos = _label_set(snap, "serving_slo_value", "slo")
        targets = _label_set(snap, "serving_slo_target", "slo")
        if not slos <= targets:
            problems.append(f"SLOs without a target gauge: "
                            f"{sorted(map(str, slos - targets))}")
        for labels, v in _series_values(snap, "serving_slo_compliant"):
            if v not in (0, 0.0, 1, 1.0):
                problems.append(f"serving_slo_compliant{labels}: must "
                                f"be 0 or 1, got {v!r}")
        for labels, v in _series_values(snap, "serving_slo_burn_rate"):
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v >= 0):
                problems.append(f"serving_slo_burn_rate{labels}: must "
                                f"be finite and >= 0, got {v!r}")
    return problems


def validate_chrome_trace(trace: Dict) -> List[str]:
    """Problems in a Chrome trace-event JSON object.

    Checks the event schema Perfetto/chrome://tracing require: a
    ``traceEvents`` list whose entries carry name/ph/pid/tid, numeric
    finite ``ts`` for timed phases, and a non-negative ``dur`` on every
    complete ("X") event.
    """
    problems: List[str] = []
    if not isinstance(trace, dict):
        return [f"trace must be a dict, got {type(trace).__name__}"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["trace.traceEvents must be a list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: event must be a dict")
            continue
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            problems.append(f"{where}: missing name")
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: bad phase {ph!r}")
            continue
        for idkey in ("pid", "tid"):
            if not isinstance(ev.get(idkey), int):
                problems.append(f"{where}: {idkey} must be an int")
        if ph != "M":
            ts = ev.get("ts")
            if (not isinstance(ts, (int, float)) or not math.isfinite(ts)
                    or ts < 0):
                problems.append(f"{where}: ts must be a finite "
                                f"non-negative number")
        if ph == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float))
                    or not math.isfinite(dur) or dur < 0):
                problems.append(f"{where}: X event needs non-negative dur")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args must be a dict")
    return problems
