"""Lightweight step-span tracing with Chrome trace-event JSON export.

Answers the question the metrics registry cannot: *where did this step's
time go?* Every host-side serving phase (schedule / prefill chunk /
decode batch / draft window / verify window) runs inside a
``with tracer.span(...)`` block, and the scheduler emits per-request
lifecycle spans (waiting → prefill → decode, preemption gaps included)
onto a per-request track. Events land in a bounded ring buffer — a
long-running engine never grows without bound; old events fall off.

``export_chrome()`` writes the standard Chrome trace-event JSON
(``{"traceEvents": [...]}``, "X" complete events with microsecond
``ts``/``dur``), loadable in Perfetto / chrome://tracing as-is. Span
begin/ends are recorded host-side only — never inside traced/jitted
code — so tracing changes no compiled program.

``xla_annotations=True`` additionally wraps each span body in
``jax.profiler.TraceAnnotation`` (when available), so engine spans line
up with XLA device rows when a jax profiler session is active on a real
backend. Import/runtime failures degrade to plain spans — the tracer
itself never requires jax.

    tr = Tracer()
    with tr.span("decode_step", step=i):
        ...
    tr.export_chrome("trace.json")       # open in ui.perfetto.dev
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import time
from typing import Dict, List, Optional

ENGINE_TRACK = 0            # tid 0: engine-step phases
REQUEST_TRACK_BASE = 1      # tid rid + 1: per-request lifecycle spans


@dataclasses.dataclass
class SpanHandle:
    """An open span (returned by :meth:`Tracer.begin`)."""
    name: str
    track: int
    t0_us: float
    args: Dict
    closed: bool = False


class Tracer:
    """Ring-buffered span recorder.

    ``clock`` is injectable (seconds; shared with the engine/registry) so
    tests get deterministic timestamps; exported ``ts`` are microseconds
    relative to tracer construction. ``enabled=False`` turns every
    operation into a cheap no-op.
    """

    def __init__(self, clock=time.monotonic, capacity: int = 65536,
                 enabled: bool = True, xla_annotations: bool = False,
                 pid: int = 0):
        if capacity < 1:
            raise ValueError(capacity)
        self._clock = clock
        self._t0 = clock()
        self.enabled = enabled
        self.xla_annotations = xla_annotations
        self.pid = pid
        self.capacity = capacity
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._open: List[SpanHandle] = []
        self._track_names: Dict[int, str] = {}
        self.dropped = 0            # events evicted by the ring buffer

    # -- recording ---------------------------------------------------------

    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _push(self, event: Dict) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(event)

    def set_track_name(self, track: int, name: str) -> None:
        """Name a tid (rendered as a thread row in Perfetto)."""
        if self.enabled:
            self._track_names[track] = name

    def begin(self, name: str, track: int = ENGINE_TRACK,
              **args) -> Optional[SpanHandle]:
        """Open a span; close it with :meth:`end`. For spans whose begin
        and end live in different call sites (request lifecycle phases);
        block-scoped work should use :meth:`span`."""
        if not self.enabled:
            return None
        h = SpanHandle(name=name, track=track, t0_us=self._now_us(),
                       args=dict(args))
        self._open.append(h)
        return h

    def end(self, handle: Optional[SpanHandle]) -> None:
        if handle is None or not self.enabled or handle.closed:
            return
        handle.closed = True
        try:
            self._open.remove(handle)
        except ValueError:
            pass
        self._push({"name": handle.name, "ph": "X", "ts": handle.t0_us,
                    "dur": self._now_us() - handle.t0_us,
                    "pid": self.pid, "tid": handle.track,
                    "args": handle.args})

    @contextlib.contextmanager
    def span(self, name: str, track: int = ENGINE_TRACK, **args):
        """Record the with-block as one complete ("X") trace event."""
        if not self.enabled:
            yield
            return
        ann = None
        if self.xla_annotations:
            try:
                import jax
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:
                ann = None
        h = self.begin(name, track=track, **args)
        try:
            yield
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            self.end(h)

    def instant(self, name: str, track: int = ENGINE_TRACK, **args) -> None:
        """Record a zero-duration marker (Chrome "i" instant event)."""
        if not self.enabled:
            return
        self._push({"name": name, "ph": "i", "ts": self._now_us(),
                    "pid": self.pid, "tid": track, "s": "t",
                    "args": dict(args)})

    # -- export ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    def export(self) -> Dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        Still-open spans are flushed as complete events with duration up
        to now (they stay open in the tracer — export is read-only).
        """
        events: List[Dict] = [
            {"name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
             "args": {"name": "sparqle-serving"}}]
        for track in sorted(self._track_names):
            events.append({"name": "thread_name", "ph": "M",
                           "pid": self.pid, "tid": track,
                           "args": {"name": self._track_names[track]}})
        events.extend(self._events)
        now = self._now_us()
        for h in self._open:
            events.append({"name": h.name, "ph": "X", "ts": h.t0_us,
                           "dur": now - h.t0_us, "pid": self.pid,
                           "tid": h.track, "args": dict(h.args)})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> Dict:
        """Write :meth:`export` to ``path``; returns the trace dict."""
        trace = self.export()
        with open(path, "w") as f:
            json.dump(trace, f)
            f.write("\n")
        return trace
