"""Declarative serving SLOs: sliding-window percentiles + watchdog.

An :class:`SLO` pins a percentile of a serving signal (TTFT, TPOT, or
scheduler queue depth) under a target; the :class:`SLOMonitor` evaluates
every attached SLO online over a bounded sliding window of the most
recent samples and drives the watchdog metrics:

  * ``serving_slo_value{slo=}``            — current windowed percentile,
  * ``serving_slo_target{slo=}``           — the declared target,
  * ``serving_slo_compliant{slo=}``        — 1 while the percentile is
    within target, 0 while violating,
  * ``serving_slo_burn_rate{slo=}``        — error-budget burn: the
    fraction of window samples over target divided by the budget
    ``1 - q/100`` (1.0 = burning exactly the allowed budget),
  * ``serving_slo_violations_total{slo=}`` — edge-triggered count of
    compliant -> violating transitions (a sustained violation counts
    once, not per sample),
  * ``serving_slo_samples_total{slo=}``    — samples folded in.

Each compliant -> violating edge also drops an ``slo_violation`` instant
on the tracer's engine track, so violations line up with the engine-step
spans in Perfetto. Everything is deterministic given the sample stream:
the window percentile is nearest-rank (no interpolation), so tests can
pin exact trigger points with a synthetic clock.

Engine integration: ``Engine(..., slos=[...])`` feeds ``ttft``/``tpot``
observations from ``_emit`` and ``queue_depth`` once per scheduler
iteration; ``launch/serve.py --slo`` and ``bench_serving --slo`` parse
specs like ``ttft:p95<0.5`` (seconds) / ``queue_depth:p50<4`` (requests)
from the command line (docs/observability.md §SLOs).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import re
from typing import Dict, Iterable, List, Optional

SIGNALS = ("ttft", "tpot", "queue_depth")
_SIGNAL_UNITS = {"ttft": "seconds", "tpot": "seconds",
                 "queue_depth": "requests"}

_SPEC_RE = re.compile(
    r"^(?P<signal>[a-z_]+):p(?P<q>[0-9]+(?:\.[0-9]+)?)"
    r"<(?P<target>[0-9.eE+\-]+)$")


@dataclasses.dataclass(frozen=True)
class SLO:
    """One objective: ``percentile(signal, window) <= target``."""

    name: str                    # label value (defaults to the spec text)
    signal: str                  # "ttft" | "tpot" | "queue_depth"
    target: float                # threshold (seconds or requests)
    percentile: float = 95.0     # windowed percentile under the target
    window: int = 64             # sliding-window length (samples)
    min_samples: int = 1         # don't judge before this many samples

    def __post_init__(self):
        if self.signal not in SIGNALS:
            raise ValueError(f"SLO {self.name}: unknown signal "
                             f"{self.signal!r} (expected one of {SIGNALS})")
        if not 0.0 < self.percentile <= 100.0:
            raise ValueError(f"SLO {self.name}: percentile must be in "
                             f"(0, 100], got {self.percentile}")
        if self.window < 1 or self.min_samples < 1:
            raise ValueError(f"SLO {self.name}: window and min_samples "
                             f"must be >= 1")
        if not math.isfinite(self.target):
            raise ValueError(f"SLO {self.name}: non-finite target")

    @property
    def unit(self) -> str:
        return _SIGNAL_UNITS[self.signal]


def parse_slo(spec: str, *, window: int = 64) -> SLO:
    """Parse a CLI spec like ``ttft:p95<0.25`` into an :class:`SLO`.

    Format: ``<signal>:p<percentile><<target>`` with the target in the
    signal's unit (seconds for ttft/tpot, requests for queue_depth).
    """
    m = _SPEC_RE.match(spec.strip())
    if not m:
        raise ValueError(
            f"bad SLO spec {spec!r}: expected <signal>:pQQ<target, e.g. "
            f"'ttft:p95<0.25' or 'queue_depth:p50<4'")
    return SLO(name=spec.strip(), signal=m.group("signal"),
               target=float(m.group("target")),
               percentile=float(m.group("q")), window=window)


def parse_slo_list(text: str, *, window: int = 64) -> List[SLO]:
    """Parse a comma-separated list of SLO specs (empty -> [])."""
    return [parse_slo(part, window=window)
            for part in text.split(",") if part.strip()]


class SlidingWindow:
    """Bounded sample window with deterministic nearest-rank percentiles.

    Nearest-rank (sorted[ceil(q/100 * n) - 1]) rather than interpolated:
    the result is always an observed sample, so a test that injects a
    spike knows exactly which value the watchdog judges.
    """

    def __init__(self, maxlen: int):
        self._values: collections.deque = collections.deque(maxlen=maxlen)
        self.total = 0

    def __len__(self) -> int:
        return len(self._values)

    def observe(self, value: float) -> None:
        value = float(value)
        if math.isnan(value):
            raise ValueError("SlidingWindow: NaN observation")
        self._values.append(value)
        self.total += 1

    def percentile(self, q: float) -> float:
        if not 0.0 < q <= 100.0:
            raise ValueError(q)
        if not self._values:
            return float("nan")
        vals = sorted(self._values)
        rank = math.ceil(q / 100.0 * len(vals))
        return vals[max(rank, 1) - 1]

    def over_fraction(self, threshold: float) -> float:
        """Fraction of window samples strictly above ``threshold``."""
        if not self._values:
            return 0.0
        n_over = sum(1 for v in self._values if v > threshold)
        return n_over / len(self._values)


class _SLOState:
    __slots__ = ("slo", "window", "violating")

    def __init__(self, slo: SLO):
        self.slo = slo
        self.window = SlidingWindow(slo.window)
        self.violating = False


class SLOMonitor:
    """Evaluates a set of SLOs online against an Observability bundle.

    ``observe(signal, value)`` folds one sample into every SLO watching
    that signal and re-judges it immediately; gauge state is always
    current (no refresh step). Violations are edge-triggered: the
    counter and the tracer instant fire on the compliant -> violating
    transition only, and recovery re-arms them.
    """

    def __init__(self, slos: Iterable[SLO], obs):
        self.obs = obs
        self._states: List[_SLOState] = []
        names = set()
        r = obs.registry
        self._g_value = r.gauge(
            "serving_slo_value", "current windowed percentile of the "
            "SLO's signal", unit="value", labelnames=("slo",))
        self._g_target = r.gauge(
            "serving_slo_target", "declared SLO target", unit="value",
            labelnames=("slo",))
        self._g_compliant = r.gauge(
            "serving_slo_compliant", "1 while the SLO is met, 0 while "
            "violating", unit="ratio", labelnames=("slo",))
        self._g_burn = r.gauge(
            "serving_slo_burn_rate", "fraction of window samples over "
            "target / error budget (1-q/100); >1 burns budget faster "
            "than allowed", unit="ratio", labelnames=("slo",))
        self._c_violations = r.counter(
            "serving_slo_violations_total", "compliant->violating edges "
            "(a sustained violation counts once)", unit="events",
            labelnames=("slo",))
        self._c_samples = r.counter(
            "serving_slo_samples_total", "signal samples folded into "
            "SLO windows", unit="events", labelnames=("slo",))
        for slo in slos:
            if slo.name in names:
                raise ValueError(f"duplicate SLO name {slo.name!r}")
            names.add(slo.name)
            self._states.append(_SLOState(slo))
            self._g_target.set(slo.target, slo=slo.name)
            self._g_compliant.set(1.0, slo=slo.name)

    @property
    def slos(self) -> List[SLO]:
        return [st.slo for st in self._states]

    def observe(self, signal: str, value: float) -> None:
        if math.isnan(value):
            return
        for st in self._states:
            if st.slo.signal != signal:
                continue
            st.window.observe(value)
            self._c_samples.inc(slo=st.slo.name)
            self._judge(st)

    def _judge(self, st: _SLOState) -> None:
        slo = st.slo
        if len(st.window) < slo.min_samples:
            return
        p = st.window.percentile(slo.percentile)
        budget = max(1.0 - slo.percentile / 100.0, 1e-9)
        burn = st.window.over_fraction(slo.target) / budget
        violating = p > slo.target
        self._g_value.set(p, slo=slo.name)
        self._g_burn.set(burn, slo=slo.name)
        self._g_compliant.set(0.0 if violating else 1.0, slo=slo.name)
        if violating and not st.violating:
            self._c_violations.inc(slo=slo.name)
            self.obs.tracer.instant(
                "slo_violation", slo=slo.name, signal=slo.signal,
                value=p, target=slo.target, burn_rate=burn)
        st.violating = violating

    def violations(self) -> Dict[str, int]:
        """{slo name: edge-triggered violation count}."""
        return {st.slo.name:
                int(self._c_violations.value(slo=st.slo.name))
                for st in self._states}

    def report(self) -> List[Dict[str, object]]:
        """JSON-ready per-SLO status (what serve.py / the bench print)."""
        out = []
        for st in self._states:
            slo = st.slo
            n = len(st.window)
            p = (st.window.percentile(slo.percentile) if n
                 else float("nan"))
            out.append({
                "slo": slo.name, "signal": slo.signal, "unit": slo.unit,
                "percentile": slo.percentile, "target": slo.target,
                "value": p, "samples": st.window.total,
                "violating": st.violating,
                "violations": int(
                    self._c_violations.value(slo=slo.name)),
                "burn_rate": (float(self._g_burn.value(slo=slo.name))
                              if n >= slo.min_samples else 0.0),
            })
        return out


def attach_engine_slos(engine, slos: Optional[Iterable[SLO]]
                       ) -> Optional[SLOMonitor]:
    """Build a monitor against an engine's Observability (None -> None)."""
    slos = list(slos or [])
    if not slos:
        return None
    return SLOMonitor(slos, engine.obs)
