"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The serving stack's quantitative spine (ISSUE 7): every engine layer
registers named metrics here instead of growing ad-hoc attributes, and
everything downstream — ``Engine.aggregate_stats()``, ``serve.py
--metrics-out``, ``bench_serving --json`` percentile gating — reads the
same registry. Deliberately dependency-free (no prometheus_client): the
paper repro must run in a hermetic container, and the three metric kinds
we need are small.

Conventions (enforced):

  * names match ``^[a-z][a-z0-9_]*$`` (checked at registration AND by
    ``benchmarks/check_metrics_schema.py`` over emitted artifacts);
  * every metric declares a ``unit`` ("seconds", "tokens", "bytes",
    "pages", "ratio", ...) — carried through snapshots so dashboards
    don't have to guess;
  * labels are declared up front (``labelnames``) and passed as kwargs:
    ``hist.observe(dt, phase="prefill")``.

Time never comes from ``time.monotonic`` directly: the registry owns an
injectable ``clock`` (shared with the engine and tracer) so tests drive
deterministic latency histograms.

    reg = MetricsRegistry()
    ttft = reg.histogram("serving_ttft_seconds", "arrival to first token",
                         unit="seconds")
    ttft.observe(0.12)
    reg.snapshot()                       # JSON-ready dict
    print(reg.render_text())             # Prometheus-style exposition
"""
from __future__ import annotations

import contextlib
import math
import re
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

METRIC_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")

# default latency buckets (seconds): CPU-interpret serving steps land in
# the ms..s range; sub-ms and >30 s tails overflow into the edge buckets
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0)


def _labels_key(labelnames: Tuple[str, ...], labels: Dict) -> Tuple:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"expected labels {labelnames}, got {tuple(sorted(labels))}")
    return tuple(str(labels[n]) for n in labelnames)


class _Metric:
    kind = "abstract"

    def __init__(self, name: str, help: str, unit: str,
                 labelnames: Tuple[str, ...], clock):
        self.name = name
        self.help = help
        self.unit = unit
        self.labelnames = labelnames
        self._clock = clock
        self._series: Dict[Tuple, object] = {}

    def _key(self, labels: Dict) -> Tuple:
        return _labels_key(self.labelnames, labels)

    def series_labels(self) -> List[Dict[str, str]]:
        return [dict(zip(self.labelnames, k)) for k in self._series]


class Counter(_Metric):
    """Monotonic accumulator. ``inc`` rejects negative amounts."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class Gauge(_Metric):
    """Last-write-wins point-in-time value."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), float("nan")))


class _HistSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)      # +1 overflow (+Inf)
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    """Fixed-bucket histogram with percentile estimation.

    Buckets are upper bounds (ascending); an implicit +Inf bucket catches
    the overflow. ``percentile`` linearly interpolates inside the bucket
    containing the rank — resolution is the bucket width, which is the
    honest precision of a fixed-bucket histogram (the regression gate
    treats percentiles as timings, tolerance 5x, so this is plenty).
    """

    kind = "histogram"

    def __init__(self, name, help, unit, labelnames, clock,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, unit, labelnames, clock)
        bs = tuple(float(b) for b in buckets)
        if not bs or list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram {name}: buckets must be a "
                             f"non-empty ascending sequence, got {buckets}")
        if not all(math.isfinite(b) for b in bs):
            raise ValueError(f"histogram {name}: buckets must be finite "
                             f"(+Inf is implicit), got {buckets}")
        self.buckets = bs

    def _get(self, labels: Dict) -> _HistSeries:
        k = self._key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = _HistSeries(len(self.buckets))
        return s

    def observe(self, value: float, **labels) -> None:
        value = float(value)
        if math.isnan(value):
            raise ValueError(f"histogram {self.name}: NaN observation "
                             f"(guard at the call site)")
        s = self._get(labels)
        i = 0
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                break
        else:
            i = len(self.buckets)                # overflow bucket
        s.counts[i] += 1
        s.sum += value
        s.count += 1

    @contextlib.contextmanager
    def time(self, **labels):
        """Observe the wall time of a with-block (registry clock)."""
        t0 = self._clock()
        try:
            yield
        finally:
            self.observe(self._clock() - t0, **labels)

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return s.count if s else 0

    def sum(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return s.sum if s else 0.0

    def mean(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return s.sum / s.count if s and s.count else float("nan")

    def percentile(self, q: float, **labels) -> float:
        """Bucket-interpolated percentile, q in [0, 100].

        Rank q lands in some bucket; the return value interpolates
        linearly between that bucket's bounds. Observations past the last
        finite bound clamp to it (an overflow bucket has no upper edge).
        """
        if not 0.0 <= q <= 100.0:
            raise ValueError(q)
        s = self._series.get(self._key(labels))
        if s is None or s.count == 0:
            return float("nan")
        target = max(q / 100.0 * s.count, 1e-12)
        cum = 0.0
        lo = 0.0
        for ub, c in zip(self.buckets, s.counts):
            if c and cum + c >= target:
                return lo + (ub - lo) * (target - cum) / c
            cum += c
            lo = ub
        return self.buckets[-1]                  # overflow: clamp


class MetricsRegistry:
    """Named-metric store: create-or-get, snapshot, text exposition.

    ``clock`` is shared with every ``Histogram.time`` block (injectable
    for deterministic tests). Re-registering an existing name returns the
    existing metric when kind/unit/labels agree and raises otherwise —
    two subsystems silently disagreeing about a metric is a bug.
    """

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self._metrics: Dict[str, _Metric] = {}

    # -- registration ------------------------------------------------------

    def _register(self, cls, name: str, help: str, unit: str,
                  labelnames: Iterable[str], **kw) -> _Metric:
        if not METRIC_NAME_RE.match(name):
            raise ValueError(f"metric name {name!r} must match "
                             f"{METRIC_NAME_RE.pattern}")
        if not unit:
            raise ValueError(f"metric {name}: declare a unit "
                             f"('seconds', 'tokens', 'ratio', ...)")
        labelnames = tuple(labelnames)
        for ln in labelnames:
            if not METRIC_NAME_RE.match(ln):
                raise ValueError(f"metric {name}: bad label name {ln!r}")
        existing = self._metrics.get(name)
        if existing is not None:
            if (type(existing) is not cls or existing.unit != unit
                    or existing.labelnames != labelnames):
                raise ValueError(
                    f"metric {name} re-registered with a different "
                    f"kind/unit/labels ({existing.kind}/{existing.unit}/"
                    f"{existing.labelnames})")
            return existing
        m = cls(name, help, unit, labelnames, self.clock, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "", unit: str = "1",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._register(Counter, name, help, unit, labelnames)

    def gauge(self, name: str, help: str = "", unit: str = "1",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, unit, labelnames)

    def histogram(self, name: str, help: str = "", unit: str = "1",
                  labelnames: Iterable[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram, name, help, unit, labelnames,
                              buckets=buckets)

    # -- access ------------------------------------------------------------

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def value(self, name: str, **labels) -> float:
        """Scalar value of a counter/gauge series (nan if absent)."""
        m = self._metrics.get(name)
        if m is None:
            return float("nan")
        if isinstance(m, Histogram):
            raise TypeError(f"{name} is a histogram; use get().percentile")
        return m.value(**labels)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # -- export ------------------------------------------------------------

    def snapshot(self) -> Dict:
        """JSON-ready dict of every metric and series.

        Histogram series carry raw bucket counts (per-bucket, aligned to
        ``buckets`` + one overflow slot) plus precomputed p50/p90/p99 —
        the quantities the bench gate and dashboards read most.
        """
        out: Dict[str, Dict] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            entry = {"type": m.kind, "unit": m.unit, "help": m.help,
                     "series": []}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
                for labels in m.series_labels():
                    s = m._series[m._key(labels)]
                    entry["series"].append({
                        "labels": labels, "count": s.count, "sum": s.sum,
                        "bucket_counts": list(s.counts),
                        "p50": m.percentile(50, **labels),
                        "p90": m.percentile(90, **labels),
                        "p99": m.percentile(99, **labels)})
            else:
                for labels in m.series_labels():
                    entry["series"].append(
                        {"labels": labels, "value": m.value(**labels)})
            out[name] = entry
        return out

    def render_text(self) -> str:
        """Prometheus-style text exposition (OpenMetrics-ish ``# UNIT``).

        Histogram buckets render cumulatively with ``le`` labels plus the
        standard ``_sum`` / ``_count`` series.
        """
        def fmt_labels(labels: Dict[str, str], extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in labels.items()]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.append(f"# UNIT {name} {m.unit}")
            if isinstance(m, Histogram):
                for labels in m.series_labels():
                    s = m._series[m._key(labels)]
                    cum = 0
                    for ub, c in zip(m.buckets, s.counts):
                        cum += c
                        le = fmt_labels(labels, 'le="%g"' % ub)
                        lines.append(f"{name}_bucket{le} {cum}")
                    inf_label = fmt_labels(labels, 'le="+Inf"')
                    lines.append(f"{name}_bucket{inf_label} {s.count}")
                    lines.append(f"{name}_sum{fmt_labels(labels)} {s.sum:g}")
                    lines.append(f"{name}_count{fmt_labels(labels)} "
                                 f"{s.count}")
            else:
                for labels in m.series_labels():
                    lines.append(f"{name}{fmt_labels(labels)} "
                                 f"{m.value(**labels):g}")
        return "\n".join(lines) + "\n"
