"""Dependency-free serving observability: metrics registry + span tracing.

  * metrics  — process-local registry of named counters / gauges /
               fixed-bucket histograms (labels, declared units,
               percentile estimation, snapshot-to-dict, Prometheus-style
               ``render_text``), with an injectable clock
  * trace    — ring-buffered span tracer exporting Chrome trace-event
               JSON (Perfetto-loadable), per-request lifecycle tracks,
               optional ``jax.profiler.TraceAnnotation`` pass-through
  * validate — artifact schema validators shared by tests and the CI
               metric-name/unit check
  * attribution — compiled-HLO per-step cost attribution joined with
               measured step times: roofline utilization + cost-model
               drift gauges
  * slo      — declarative serving SLOs (sliding-window percentiles,
               burn rate, edge-triggered violation watchdog)

:class:`Observability` bundles one registry + one tracer around a shared
clock; the serving engine owns one and threads it through the scheduler,
page pool and speculative decoder (see docs/observability.md).
"""
from __future__ import annotations

import time

from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, METRIC_NAME_RE,
                               Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.trace import (ENGINE_TRACK, REQUEST_TRACK_BASE, SpanHandle,
                             Tracer)
from repro.obs.validate import validate_chrome_trace, validate_snapshot


class Observability:
    """One registry + one tracer sharing one (injectable) clock.

    The unit every instrumented subsystem receives: the engine creates
    one per instance (metrics are process-local to an engine, matching
    ``aggregate_stats``'s scope) and hands it to the scheduler and pool.
    ``trace=False`` keeps the registry live but makes spans no-ops.
    """

    def __init__(self, clock=time.monotonic, trace_capacity: int = 65536,
                 trace: bool = True, xla_annotations: bool = False):
        self.registry = MetricsRegistry(clock=clock)
        self.tracer = Tracer(clock=clock, capacity=trace_capacity,
                             enabled=trace,
                             xla_annotations=xla_annotations)


# attribution/slo import AFTER Observability: they are host-only leaf
# modules importing repro.obs.metrics / repro.obs.trace directly, and
# re-exporting them here keeps `from repro.obs import SLO, ...` working
# without a package-init cycle
from repro.obs.attribution import StepAttribution, StepCost  # noqa: E402
from repro.obs.slo import (SLO, SLOMonitor, SlidingWindow,  # noqa: E402
                           attach_engine_slos, parse_slo, parse_slo_list)

__all__ = ["Counter", "DEFAULT_LATENCY_BUCKETS", "ENGINE_TRACK", "Gauge",
           "Histogram", "METRIC_NAME_RE", "MetricsRegistry",
           "Observability", "REQUEST_TRACK_BASE", "SLO", "SLOMonitor",
           "SlidingWindow", "SpanHandle", "StepAttribution", "StepCost",
           "Tracer", "attach_engine_slos", "parse_slo", "parse_slo_list",
           "validate_chrome_trace", "validate_snapshot"]
