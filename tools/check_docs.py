"""Docs linter: keep README.md and docs/*.md honest against the tree.

    python tools/check_docs.py [--root PATH]

Three checks over every markdown file (README.md + docs/*.md):

1. **File paths.** Inline-code spans that look like repo paths
   (``docs/serving.md``, ``core/packing.py::predicted_wire_bytes``,
   ``serving/kv_pool.py``) must resolve against the repo root, ``src/``
   or ``src/repro/`` — docs routinely abbreviate module paths the way
   the code imports them. Bare filenames with a known extension
   (``serve.py``) must exist *somewhere* in the tree. Math-looking
   spans (``hd/2``, shapes, calls with parens) are ignored.
2. **CLI flags.** Every ``--flag`` mentioned in inline code or fenced
   shell/python blocks must be a real argparse option somewhere under
   ``src/``, ``benchmarks/`` or ``tools/`` (external tool flags like
   ``--xla_*`` are allowlisted).
3. **Cross-references.** Every ``[[name]]`` wiki-style link must
   resolve to ``docs/name.md``.

Exit 0 when clean, 1 with a per-file report otherwise. CI runs this in
the lint job; it needs nothing beyond the standard library.
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

PATH_EXTENSIONS = (".py", ".md", ".json", ".jsonl", ".txt", ".yml",
                   ".yaml", ".toml", ".sh", ".cfg", ".ini")
# dirs whose names may open an extension-less path reference
# (``src/repro/core``); anything else without an extension is prose
TOP_DIRS = ("src", "docs", "benchmarks", "tests", "tools", ".github")
# module-style prefixes docs use as shorthand for src/ and src/repro/
RESOLVE_PREFIXES = ("", "src", "src/repro")
# flags owned by external tools, not our argparse surfaces
FLAG_ALLOWLIST_PREFIXES = ("--xla",)

INLINE_CODE = re.compile(r"`([^`\n]+)`")
FENCED_BLOCK = re.compile(r"^```.*?\n(.*?)^```", re.M | re.S)
WIKI_REF = re.compile(r"\[\[([\w-]+)\]\]")
FLAG = re.compile(r"(?<![\w-])--[a-zA-Z][\w-]*")
PATHISH = re.compile(r"^[\w./-]+$")
ADD_ARGUMENT = re.compile(r"add_argument\(\s*['\"](--[\w-]+)['\"]")


def markdown_files(root: pathlib.Path) -> list:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.is_file()]


def argparse_flags(root: pathlib.Path) -> set:
    flags = set()
    for top in ("src", "benchmarks", "tools"):
        for py in (root / top).rglob("*.py"):
            flags.update(ADD_ARGUMENT.findall(py.read_text()))
    return flags


def path_candidates(spans: list) -> list:
    """Inline-code spans that plausibly name a repo file or directory."""
    out = []
    for span in spans:
        token = span.split("::", 1)[0].rstrip("/")
        if not PATHISH.match(token):
            continue  # spaces, parens, commas, operators: prose or math
        if token.endswith(PATH_EXTENSIONS):
            out.append(token)
        elif "/" in token and token.split("/", 1)[0] in TOP_DIRS:
            out.append(token)  # extension-less dir ref like src/repro/core
    return out


def resolve_path(root: pathlib.Path, token: str) -> bool:
    if "/" in token:
        return any((root / pre / token).exists() for pre in RESOLVE_PREFIXES)
    # bare filename (``serve.py``): any file with that basename counts
    if next(root.rglob(token), None) is not None:
        return True
    return False


def check_file(md: pathlib.Path, root: pathlib.Path,
               known_flags: set) -> list:
    text = md.read_text()
    problems = []

    fenced = FENCED_BLOCK.findall(text)
    prose = FENCED_BLOCK.sub("", text)
    inline = INLINE_CODE.findall(prose)

    for token in path_candidates(inline):
        if not resolve_path(root, token):
            problems.append(f"stale path `{token}`")

    code_text = "\n".join(inline + fenced)
    for flag in sorted(set(FLAG.findall(code_text))):
        if flag.startswith(FLAG_ALLOWLIST_PREFIXES):
            continue
        if flag not in known_flags:
            problems.append(f"unknown CLI flag `{flag}`")

    for name in WIKI_REF.findall(text):
        if not (root / "docs" / f"{name}.md").is_file():
            problems.append(f"broken cross-reference [[{name}]]")

    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="repo root (default: this script's parent dir)")
    args = ap.parse_args(argv)
    root = (pathlib.Path(args.root) if args.root
            else pathlib.Path(__file__).resolve().parent.parent)

    known_flags = argparse_flags(root)
    files = markdown_files(root)
    failures = 0
    for md in files:
        problems = check_file(md, root, known_flags)
        rel = md.relative_to(root)
        if problems:
            failures += len(problems)
            print(f"FAIL {rel}")
            for p in problems:
                print(f"  - {p}")
        else:
            print(f"ok   {rel}")
    if failures:
        print(f"\n{failures} stale reference(s) across {len(files)} files")
        return 1
    print(f"\nall {len(files)} markdown files clean "
          f"({len(known_flags)} known CLI flags)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
