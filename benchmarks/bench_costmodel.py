"""Paper Fig. 6 / §5.1-§5.2: accelerator cost-model reproduction.

Drives the analytical SPARQLe-vs-dense accelerator model with the paper's
three models at their REPORTED sparsities and compares all 12 improvement
numbers against the paper's claims. ``--calibrate`` grid-searches the
dataflow knobs the paper leaves unspecified (SRAM tile reuse, decode
batch) to best fit those 12 numbers; the committed defaults come from
that search.
"""
from __future__ import annotations

import argparse
import itertools
from typing import Dict

from repro.core.costmodel import (HardwareConfig, PAPER_CLAIMS, PAPER_MODELS,
                                  PAPER_SPARSITY, area_power_overhead,
                                  evaluate_model)

CLAIM_KEYS = ("ttft_latency_pct", "tpot_latency_pct",
              "prefill_energy_pct", "decode_energy_pct")


def model_errors(hw: HardwareConfig, decode_batch: int,
                 prefill_tokens: int = 2048) -> Dict[str, Dict[str, float]]:
    out = {}
    for name, shape in PAPER_MODELS.items():
        rep = evaluate_model(shape, PAPER_SPARSITY[name], hw,
                             prefill_tokens=prefill_tokens,
                             decode_batch=decode_batch)
        out[name] = rep.improvements()
    return out


def fit_error(preds) -> float:
    err = 0.0
    for name, claims in PAPER_CLAIMS.items():
        for key, claim in zip(CLAIM_KEYS, claims):
            err += (preds[name][key] - claim) ** 2
    return err


def calibrate() -> tuple:
    best = None
    for tm, tn, db, leak in itertools.product(
            (32, 64, 128), (32, 64, 128), (16, 24, 32, 48, 64),
            (50.0, 150.0, 400.0)):
        hw = HardwareConfig(tile_m=tm, tile_n=tn, leak_pj_per_cycle=leak)
        preds = model_errors(hw, db)
        e = fit_error(preds)
        if best is None or e < best[0]:
            best = (e, tm, tn, db, leak)
    return best


def run(emit, calibrate_flag: bool = False) -> None:
    if calibrate_flag:
        e, tm, tn, db, leak = calibrate()
        emit("costmodel/calib_rmse", (e / 12) ** 0.5,
             f"tile_m={tm} tile_n={tn} decode_batch={db} leak={leak}")
        hw = HardwareConfig(tile_m=tm, tile_n=tn, leak_pj_per_cycle=leak)
        decode_batch = db
    else:
        hw = HardwareConfig()
        decode_batch = CALIB_DECODE_BATCH

    preds = model_errors(hw, decode_batch)
    for name, claims in PAPER_CLAIMS.items():
        imp = preds[name]
        for key, claim in zip(CLAIM_KEYS, claims):
            emit(f"costmodel/{name}/{key}", imp[key],
                 f"paper={claim} (delta {imp[key]-claim:+.1f}pp)")
        emit(f"costmodel/{name}/prefill_transfer_pct",
             imp["prefill_transfer_pct"],
             "paper range 14.2-24.4 (decode) / compute 16.9-27.1")
        emit(f"costmodel/{name}/prefill_compute_pct",
             imp["prefill_compute_pct"], "paper range 16.9-27.1")

    rmse = (fit_error(preds) / 12) ** 0.5
    emit("costmodel/rmse_vs_paper", rmse, "pp over the 12 claims")

    ap = area_power_overhead(hw)
    emit("costmodel/area_overhead_pct", ap["area_overhead_pct"],
         "paper: 5.5")
    emit("costmodel/power_overhead_pct", ap["power_overhead_pct"],
         "paper: 7.0")

    # speculative-rounds extension (serving/spec_decode.py): γ LSB-only
    # draft steps + one batched verify, amortized over E[tokens/cycle]
    from repro.core.costmodel import breakeven_acceptance, evaluate_speculative
    for name, shape in PAPER_MODELS.items():
        s = PAPER_SPARSITY[name]
        rep = evaluate_speculative(shape, s, 2, 0.8,
                                   hw, decode_batch=decode_batch)
        emit(f"costmodel/{name}/spec_tpot_speedup_g2_a08",
             rep.tpot_speedup,
             f"gamma=2 alpha=0.8 s={s} (>1 = drafting wins)")
        be = breakeven_acceptance(shape, s, 2, hw,
                                  decode_batch=decode_batch)
        emit(f"costmodel/{name}/spec_breakeven_alpha_g2",
             be if be != float("inf") else -1.0,
             "-1 = never wins: draft restreams the full weight/KV "
             "stream under the §4 dataflow (docs/serving.md)")


# committed operating point (see --calibrate; re-derived in EXPERIMENTS.md)
CALIB_DECODE_BATCH = 24


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--calibrate", action="store_true")
    args = ap.parse_args()
    run(lambda n, v, d: print(f"{n},{v:.4g},{d}"), args.calibrate)


if __name__ == "__main__":
    main()
