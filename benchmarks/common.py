"""Shared benchmark utilities: a trained small LM + activation probes.

Several paper figures need a model whose activations have *learned*
structure (random-init activations are near-uniform and show little
sub-precision sparsity). ``trained_smoke_model`` trains a ~6M-param
llama-style model on the synthetic Markov corpus for a few hundred steps
and caches the checkpoint under runs/bench_model/ so every benchmark
reuses it.
"""
from __future__ import annotations

import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import model as M
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.optim.adamw import OptConfig, init_opt_state

RUNS = os.path.join(os.path.dirname(__file__), "..", "runs")


def provenance_meta(cfg: ModelConfig = None) -> Dict[str, str]:
    """Provenance stamp for benchmark ``meta`` blocks: git SHA, jax
    version, and a hash of the bench model config — enough to answer
    "what exactly produced this number" when comparing result files
    from different checkouts."""
    import dataclasses
    import hashlib
    import json as _json
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    out = {"git_sha": sha or "unknown", "jax_version": jax.__version__}
    try:
        from repro.analysis import VERSION as _an_version, ruleset_hash
        out["analyzer_version"] = _an_version
        out["analyzer_ruleset"] = ruleset_hash()
    except ImportError:
        pass
    if cfg is not None:
        blob = _json.dumps(dataclasses.asdict(cfg), sort_keys=True,
                           default=str)
        out["config_hash"] = hashlib.sha256(
            blob.encode()).hexdigest()[:16]
    return out

BENCH_CFG = ModelConfig(
    name="bench-llama-6m", family="transformer", n_layers=4, d_model=256,
    n_heads=8, n_kv_heads=4, d_ff=704, vocab=512, rope_theta=10_000.0)

BENCH_DATA = DataConfig(vocab=512, seq_len=128, global_batch=16, seed=7)


def trained_smoke_model(steps: int = 300) -> Tuple[ModelConfig, Dict]:
    """Train (or load) the benchmark LM. Returns (cfg, float params)."""
    cfg = BENCH_CFG
    ckdir = os.path.join(RUNS, "bench_model")
    latest = store.latest_step(ckdir)
    params = init_params(build_schema(cfg), jax.random.PRNGKey(0))
    if latest is not None and latest >= steps:
        return cfg, store.restore(ckdir, latest, params)
    ocfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    step = jax.jit(S.make_train_step(cfg, ocfg, S.TrainKnobs(remat=False)),
                   donate_argnums=0)
    state = S.TrainState(params, init_opt_state(params, ocfg))
    data = SyntheticLM(BENCH_DATA)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, batch)
        if i % 100 == 0:
            print(f"  [bench model] step {i} loss {float(m['loss']):.3f}",
                  flush=True)
    params = jax.device_get(state.params)
    store.save(ckdir, params, steps)
    return cfg, params


def eval_ppl(cfg: ModelConfig, params, n_batches: int = 4,
             start: int = 10_000) -> float:
    """Perplexity on held-out synthetic batches."""
    data = SyntheticLM(BENCH_DATA)
    tot, cnt = 0.0, 0
    for i in range(n_batches):
        b = data.batch_at(start + i)
        logits = M.forward(cfg, params,
                           {"tokens": jnp.asarray(b["tokens"])})
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.asarray(b["targets"])[..., None], axis=-1)[..., 0]
        tot += float(jnp.sum(lse - gold))
        cnt += gold.size
    return float(np.exp(tot / cnt))


def probe_linear_inputs(cfg: ModelConfig, params,
                        batch) -> List[Tuple[str, jax.Array]]:
    """Int8 activations entering each projection class of layer 0.

    Returns [(site, int8 activations)] for q/o/gate/up/down-equivalent
    sites — the per-site tensors behind Fig. 8 / the §3.1 statistics.
    """
    from repro.core.quantize import quantize_activations
    from repro.models.layers import rms_norm

    p0 = jax.tree_util.tree_map(lambda x: x[0],
                                params["stages"]["s0"]["p0"])
    x = M.embed_inputs(cfg, params, batch)[0]
    sites = []
    h = rms_norm(x, p0["ln"]["gamma"])                    # attn input
    sites.append(("q_proj_in", h))
    q = h @ p0["wq"]
    k = h @ p0["wk"]
    v = h @ p0["wv"]
    from repro.models.layers import AttnSpec, flash_attention, rope
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pos = jnp.arange(x.shape[1])
    qh = rope(q.reshape(*q.shape[:-1], H, hd), pos, cfg.rope_theta)
    kh = rope(k.reshape(*k.shape[:-1], KVH, hd), pos, cfg.rope_theta)
    vh = v.reshape(*v.shape[:-1], KVH, hd)
    o = flash_attention(qh, kh, vh, AttnSpec()).reshape(*x.shape[:-1],
                                                        H * hd)
    sites.append(("o_proj_in", o))
    x = x + o @ p0["wo"]
    h2 = rms_norm(x, p0["ln2"]["gamma"])
    sites.append(("gate_up_in", h2))
    act = jax.nn.silu(h2 @ p0["w_gate"]) * (h2 @ p0["w_up"])
    sites.append(("down_proj_in", act))                   # SiLU-gated

    out = []
    for name, t in sites:
        q8 = quantize_activations(t.reshape(-1, t.shape[-1]), bits=8,
                                  per_token=True).q
        out.append((name, q8))
    return out
