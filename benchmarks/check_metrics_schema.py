"""CI schema gate for observability artifacts.

    python -m benchmarks.check_metrics_schema \
        --metrics metrics.json --trace trace.json

``--metrics`` is a ``bench_serving --metrics-out`` file ({prefix:
registry snapshot}) or a bare registry snapshot (``launch/serve.py
--metrics-out``); ``--trace`` is a Chrome trace-event JSON. Both are
validated against the contracts in ``repro.obs.validate``: every metric
name matches ``^[a-z][a-z0-9_]*$`` and carries a declared unit, histogram
bucket counts are self-consistent, and every trace event is something
Perfetto / chrome://tracing will load. Exit 1 with a problem listing on
any violation — the CI lanes run this on the artifacts they upload.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.validate import (validate_attribution,
                                validate_chrome_trace, validate_snapshot)


def _looks_like_snapshot(doc: dict) -> bool:
    return any(isinstance(v, dict) and "type" in v and "series" in v
               for v in doc.values())


def check_metrics_file(path: str, require_attribution: bool = False) -> list:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        return [f"{path}: expected a JSON object"]
    snaps = {"": doc} if _looks_like_snapshot(doc) else doc
    problems = []
    n_metrics = 0
    for prefix, snap in snaps.items():
        if not isinstance(snap, dict):
            problems.append(f"{prefix or path}: snapshot is not an object")
            continue
        n_metrics += len(snap)
        pre = f"{prefix + ': ' if prefix else ''}"
        problems.extend(f"{pre}{p}" for p in validate_snapshot(snap))
        problems.extend(
            f"{pre}{p}" for p in validate_attribution(
                snap, require=require_attribution))
    print(f"{path}: {n_metrics} metrics across {len(snaps)} snapshot(s)")
    return problems


def check_trace_file(path: str) -> list:
    with open(path) as f:
        doc = json.load(f)
    problems = validate_chrome_trace(doc)
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    print(f"{path}: {len(events)} trace events")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics", action="append", default=[],
                    help="metrics snapshot JSON to validate (repeatable)")
    ap.add_argument("--trace", action="append", default=[],
                    help="Chrome trace JSON to validate (repeatable)")
    ap.add_argument("--require-attribution", action="store_true",
                    help="fail if a metrics snapshot carries no "
                         "serving_step_attr_* family (the bench gate "
                         "expects attributed engines)")
    args = ap.parse_args(argv)
    if not args.metrics and not args.trace:
        ap.error("nothing to check: pass --metrics and/or --trace")

    problems = []
    for path in args.metrics:
        problems.extend(check_metrics_file(
            path, require_attribution=args.require_attribution))
    for path in args.trace:
        problems.extend(check_trace_file(path))

    if problems:
        print(f"\nSCHEMA: {len(problems)} problem(s):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("\nall observability artifacts pass the schema gate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
