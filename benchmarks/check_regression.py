"""Bench regression gate: compare a bench JSON result to a committed baseline.

    python -m benchmarks.check_regression result.json \
        benchmarks/baselines/serving.json

Both files are ``bench_serving.py --json`` payloads ({meta, metrics}).
Metrics are gated by class:

  * **deterministic counters** (token counts, engine steps, evictions,
    stream-match flags, gamma) — must match the baseline EXACTLY. The
    bench admits requests on a step-indexed clock, so for a fixed seed
    these are machine-independent; any drift is a real behavior change.
  * **measured ratios** (sparsity, wire compression, acceptance rate,
    tokens/step, bytes/token) — relative tolerance (default 2%): they
    derive from the deterministic token streams through f32 reductions,
    so only last-ulp platform noise is expected.
  * **timings** (ttft/tpot/throughput, CPU-interpret wall clock) — NOT
    gated tightly (CI machines vary); only a catastrophic regression
    (default 5x slower than baseline) fails.

Extra metrics in the result are reported but not gated; metrics missing
from the result fail (the bench silently lost coverage).

Provenance (``meta.git_sha`` / ``meta.jax_version`` /
``meta.config_hash``, stamped by ``benchmarks.common.provenance_meta``)
is echoed for both files so a gate failure in CI says exactly which
commit and jax produced each side; a config-hash mismatch is flagged
(the comparison is then apples-to-oranges) but does not gate.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

EXACT_KEYS = ("requests", "gen_tokens", "engine_steps", "pool_evictions",
              "tokens_match", "gamma", "demotions", "promotions",
              "bytes_reclaimed")
# timing-class keys get the loose machine-speed tolerance; attribution,
# roofline and drift joins divide by measured wall time (and SLO firing
# depends on it), so they classify with the timings
TIMING_KEYS = ("ttft", "tpot", "throughput", "attr_", "roofline",
               "drift", "slo_")


def classify(name: str) -> str:
    short = name.rsplit("/", 1)[-1]
    if any(k in short for k in TIMING_KEYS):
        return "timing"
    if any(k in short for k in EXACT_KEYS):
        return "exact"
    return "ratio"


def echo_provenance(result: dict, baseline: dict) -> None:
    for tag, payload in (("result", result), ("baseline", baseline)):
        meta = payload.get("meta", {})
        print(f"{tag}: git={meta.get('git_sha', '?')[:12]} "
              f"jax={meta.get('jax_version', '?')} "
              f"config={meta.get('config_hash', '?')}")
    rc = result.get("meta", {}).get("config_hash")
    bc = baseline.get("meta", {}).get("config_hash")
    if rc and bc and rc != bc:
        print("WARNING: bench config hash differs from baseline — "
              "comparison is apples-to-oranges (regenerate the baseline)")


def check(result: dict, baseline: dict, rel_tol: float,
          timing_factor: float) -> list:
    failures = []
    for name, base in sorted(baseline["metrics"].items()):
        if name not in result["metrics"]:
            failures.append(f"{name}: missing from result (baseline "
                            f"{base:.6g})")
            continue
        got = result["metrics"][name]
        kind = classify(name)
        if kind == "exact":
            ok = got == base
            detail = f"expected exactly {base:.6g}"
        elif kind == "timing":
            # only catastrophic slowdowns gate; throughput inverts
            if "throughput" in name:
                ok = got >= base / timing_factor
                detail = f">= baseline/{timing_factor:g} ({base:.6g})"
            else:
                ok = got <= base * timing_factor
                detail = f"<= {timing_factor:g}x baseline ({base:.6g})"
        else:
            ok = math.isclose(got, base, rel_tol=rel_tol, abs_tol=1e-9)
            detail = f"within {rel_tol:.0%} of {base:.6g}"
        status = "ok" if ok else "FAIL"
        print(f"[{status}] {name}: {got:.6g} ({kind}: {detail})")
        if not ok:
            failures.append(f"{name}: {got:.6g} vs baseline {base:.6g} "
                            f"({kind})")
    extra = sorted(set(result["metrics"]) - set(baseline["metrics"]))
    for name in extra:
        print(f"[new ] {name}: {result['metrics'][name]:.6g} (not in "
              f"baseline, not gated)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("result", help="bench_serving --json output")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--rel-tol", type=float, default=0.02,
                    help="relative tolerance for measured-ratio metrics")
    ap.add_argument("--timing-factor", type=float, default=5.0,
                    help="max slowdown factor before timings fail")
    args = ap.parse_args(argv)

    with open(args.result) as f:
        result = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    echo_provenance(result, baseline)
    failures = check(result, baseline, args.rel_tol, args.timing_factor)
    if failures:
        print(f"\nREGRESSION: {len(failures)} metric(s) failed the gate:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print(f"\nall {len(baseline['metrics'])} baseline metrics within "
          f"tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
