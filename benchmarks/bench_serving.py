"""Serving-engine benchmark: continuous batching under a Poisson trace.

Drives the paged-pool engine with a Poisson request-arrival process
(exponential inter-arrival gaps, mixed prompt/generation lengths) and
reports the serving quantities the paper's system story turns on:
generation throughput, TTFT and TPOT distributions, achieved decode-time
MSB4 sub-precision sparsity, and pool pressure (evictions). Timings are
CPU interpret-mode — structural comparison only, not TPU numbers.

Arrivals are *step-indexed* (a request arrives before engine step
``ceil(t / step_dt)``), so for a fixed ``--seed`` the admission order,
the batch composition of every step, and therefore every token stream
are exactly reproducible run to run — wall-clock only feeds the timing
metrics.

A KV2 precision-ladder section always rides along (prefix
``serving_kv2``): a long-context trace on the wide-head ``KV2_CFG`` run
with the ladder disarmed, armed-but-idle (stream must match the
disarmed run byte for byte — ``serving_kv2/tokens_match_no_demotion``
is a hard invariant), and with an aggressive cold sweep, reporting
demotion/promotion counts and the peak fraction of KV HBM reclaimed
(``serving_kv2/hbm_reclaimed_pct``, floored at 25% by the bench).

``--spec-gamma N`` additionally runs the self-speculative engine
(``serving/spec_decode.py``: γ LSB4-only draft steps + one batched
full-precision verify) over the SAME trace and model, reporting draft
acceptance rate, mean emitted tokens per draft+verify cycle, and TPOT
for both engines — at temperature 0 the two token streams must be
byte-identical (``serving/spec_tokens_match``).

The bench model is *draft-friendly* (``draft_friendly_params``): a
non-negative residual stream with a scale-carrier dimension whose weight
rows are zeroed, so most activations are genuinely sub-precision and the
LSB4-only draft is a good-but-imperfect predictor — acceptance lands
strictly between 0 and 1 instead of the ~1/vocab chance agreement an
unstructured random init gives the draft.

    PYTHONPATH=src python -m benchmarks.bench_serving          # smoke
    PYTHONPATH=src python -m benchmarks.bench_serving --requests 16
    PYTHONPATH=src python -m benchmarks.bench_serving --spec-gamma 2
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.serving import (Engine, PoolConfig, SamplingParams,
                           SchedulerConfig, SpecConfig, SpeculativeEngine)

# 8 q-heads / 4 kv-heads so the same bench model shards up to 4-way on
# the model axis (--mesh): n_kv_heads, d_ff and vocab all divide
BENCH_CFG = ModelConfig(
    name="bench-serve-2l", family="transformer", n_layers=2, d_model=64,
    n_heads=8, n_kv_heads=4, head_dim=8, d_ff=128, vocab=64,
    rope_theta=10_000.0, dtype="float32")

# KV2 precision-ladder section: a wide-head long-context variant. The
# per-page HBM split is what matters here — at head_dim=32 the packed
# nibbles dominate the f32 scales (KV4 page = 20 bytes/token-head vs
# KV2 = 12), so a demoted page reclaims 40% of its bytes, vs only 25%
# at BENCH_CFG's head_dim=8 where scales are half the page.
KV2_CFG = ModelConfig(
    name="bench-kv2-2l", family="transformer", n_layers=2, d_model=64,
    n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128, vocab=64,
    rope_theta=10_000.0, dtype="float32")

STEP_DT = 0.05          # virtual seconds per engine step (admission clock)


def draft_friendly_params(cfg: ModelConfig, seed: int = 0,
                          n_spikes: int = 12, spike_lo: float = 0.12,
                          spike_hi: float = 0.9):
    """Float params whose activations are genuinely sub-precision sparse.

    Construction (per layer): the residual stream is kept NON-NEGATIVE
    (positive embeddings; positive wv/wo/w_gate/w_up/w_down so attention
    and SwiGLU outputs stay positive), and hidden dim 0 is a *scale
    carrier* — a large constant that pins every per-token int8
    quantization scale. Every weight matrix's row 0 is zeroed, so the
    carrier's (always nonzero) MSB nibble contributes nothing to any
    projection. The embedding-dominated layer-0 stream is then genuinely
    sub-precision (~0.88 measured) and the draft near-exact there; the
    ``n_spikes`` spike dims per token in [spike_lo, spike_hi] plus the
    attention-mixed deeper streams give the draft real MSB mass to drop.
    Tuning the spike density sets the measured draft acceptance rate
    strictly inside (0, 1) — the machinery the bench measures, well
    above the ~1/vocab chance floor an unstructured init gives.
    """
    rng = np.random.RandomState(seed)
    params = init_params(build_schema(cfg), jax.random.PRNGKey(seed))
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    kvd = cfg.n_kv_heads * cfg.hd

    def pos(shape, s):
        return jnp.asarray(np.abs(rng.randn(*shape)) * s, jnp.float32)

    def anysign(shape, s):
        return jnp.asarray(rng.randn(*shape) * s, jnp.float32)

    def carrier_col(w, s=0.3):
        col = jnp.asarray(np.abs(rng.randn(w.shape[0] - 1)) * s, jnp.float32)
        return w.at[0].set(0.0).at[1:, 0].set(col)

    emb = np.abs(rng.randn(v, d)) * 0.05
    for t in range(v):
        dims = rng.choice(np.arange(1, d), size=n_spikes, replace=False)
        emb[t, dims] = rng.uniform(spike_lo, spike_hi, size=n_spikes)
    emb[:, 0] = 1.0
    params["embed"]["table"] = jnp.asarray(emb, jnp.float32)

    def fix_stage(p):
        out = dict(p)
        n_l = p["wq"].shape[0]

        def rep(maker):
            return jnp.stack([maker() for _ in range(n_l)])

        out["wq"] = rep(lambda: anysign((d, d), 0.1).at[0].set(0.0))
        out["wk"] = rep(lambda: anysign((d, kvd), 0.1).at[0].set(0.0))
        out["wv"] = rep(lambda: carrier_col(pos((d, kvd), 0.02)))
        out["wo"] = rep(lambda: pos((d, d), 0.01).at[0].set(0.0))
        out["w_gate"] = rep(lambda: carrier_col(pos((d, f), 0.02)))
        out["w_up"] = rep(lambda: carrier_col(pos((d, f), 0.02)))
        out["w_down"] = rep(lambda: pos((f, d), 0.01).at[0].set(0.0))
        return out

    for stage in params["stages"].values():
        for pk, p in stage.items():
            stage[pk] = fix_stage(p)
    params["lm_head"] = anysign((d, v), 1.0).at[0].set(0.0)
    return params


def _poisson_trace(rng: np.random.Generator, n: int, rate_hz: float):
    """[(arrival_step, prompt, max_new), ...] sorted by arrival."""
    t = 0.0
    out = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate_hz)
        plen = int(rng.integers(8, 48))
        gen = int(rng.integers(4, 12))
        out.append((int(np.ceil(t / STEP_DT)),
                    rng.integers(0, BENCH_CFG.vocab, plen).tolist(), gen))
    return out


def _drive(eng, trace):
    """Step-indexed open loop: deterministic admission, wall-clock stats."""
    handles = []
    i = 0
    t0 = time.monotonic()
    step = 0
    while i < len(trace) or eng.sched.has_work():
        while i < len(trace) and trace[i][0] <= step:
            _, prompt, gen = trace[i]
            handles.append(eng.submit(
                prompt, SamplingParams(max_new_tokens=gen)))
            i += 1
        if eng.sched.has_work():
            eng.step()
        step += 1
    return handles, time.monotonic() - t0


def _drive_kv2(eng, trace):
    """_drive plus per-step tracking of the peak fraction of KV HBM
    bytes reclaimed by demotion: pool.kv_bytes_saved() over what the
    held pages would cost all-KV4 (saved + held-at-current-tier)."""
    handles = []
    i = 0
    t0 = time.monotonic()
    step = 0
    peak = 0.0
    while i < len(trace) or eng.sched.has_work():
        while i < len(trace) and trace[i][0] <= step:
            _, prompt, gen = trace[i]
            handles.append(eng.submit(
                prompt, SamplingParams(max_new_tokens=gen)))
            i += 1
        if eng.sched.has_work():
            eng.step()
            saved = eng.pool.kv_bytes_saved()
            if saved:
                peak = max(peak,
                           saved / (saved + eng.pool.kv_bytes_held()))
        step += 1
    return handles, time.monotonic() - t0, peak


def _run_kv2_ladder(emit, engines, seed: int):
    """KV2 precision-ladder section (docs/serving.md §precision ladder):
    a long-context trace on the wide-head ``KV2_CFG``, run three ways —
    ladder disarmed, armed-but-never-demoting (streams must match the
    disarmed run byte for byte), and an aggressive cold sweep
    (``demote_after_steps=1``, sparsity floor disabled) measuring how
    much KV HBM demotion reclaims. The trace fits the pool, so the
    pressure rung never fires and every counter is deterministic."""
    params = draft_friendly_params(KV2_CFG, seed=seed)
    qparams = quantize_model_params(
        params, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)
    rng = np.random.default_rng(seed + 1)
    t = 0.0
    trace = []
    for _ in range(3):
        t += rng.exponential(1.0)
        plen = int(rng.integers(48, 64))
        gen = int(rng.integers(24, 32))
        trace.append((int(np.ceil(t / STEP_DT)),
                      rng.integers(0, KV2_CFG.vocab, plen).tolist(), gen))
    sched = SchedulerConfig(max_decode_batch=4, token_budget=96,
                            prefill_chunk=32, max_pages_per_seq=12)

    def make(kv2_pages: int, **kw):
        eng = Engine(KV2_CFG, qparams,
                     pool_config=PoolConfig(n_pages=24, page_size=16,
                                            kv2_pages=kv2_pages, **kw),
                     sched_config=sched)
        eng.attribute_steps()
        return eng

    base = make(0)
    base_handles, _, _ = _drive_kv2(base, trace)
    nod = make(24, demote_after_steps=10**9)
    nod_handles, _, _ = _drive_kv2(nod, trace)
    match = (nod.pool.demotions == 0 and
             all(hb.out_tokens == hn.out_tokens
                 for hb, hn in zip(base_handles, nod_handles)))
    emit("serving_kv2/tokens_match_no_demotion", int(match),
         "armed-but-idle ladder greedy stream byte-identical to the "
         "disarmed engine (and genuinely demoted nothing)")

    eng = make(24, demote_after_steps=1, demote_min_sparsity=0.0)
    engines["serving_kv2"] = eng
    handles, wall, peak = _drive_kv2(eng, trace)
    _report(emit, "serving_kv2", handles, wall, eng)
    agg = eng.aggregate_stats()
    emit("serving_kv2/demotions", agg["pool_demotions"],
         "pages re-encoded KV4 -> KV2 (cold sweep)")
    emit("serving_kv2/promotions", agg["pool_promotions"],
         "demoted pages promoted back on touch")
    emit("serving_kv2/kv_bytes_reclaimed", agg["kv_bytes_reclaimed"],
         "cumulative KV HBM bytes freed by demotion events")
    emit("serving_kv2/hbm_reclaimed_pct", peak * 100.0,
         "peak % of held KV HBM reclaimed by demotion (vs all-KV4)")


def _make_engine(cfg, qparams, spec_gamma: int, mesh=None, slos=None):
    pool = PoolConfig(n_pages=48, page_size=16)
    sched = SchedulerConfig(max_decode_batch=8, token_budget=96,
                            prefill_chunk=32, max_pages_per_seq=8)
    if spec_gamma > 0:
        eng = SpeculativeEngine(cfg, qparams, pool_config=pool,
                                sched_config=sched,
                                spec=SpecConfig(gamma=spec_gamma),
                                mesh=mesh, slos=slos)
    else:
        eng = Engine(cfg, qparams, pool_config=pool, sched_config=sched,
                     mesh=mesh, slos=slos)
    # attribute at warm-up, before the driven trace: the compiled-HLO
    # costs feed the roofline/drift joins that _report reads back
    eng.attribute_steps()
    return eng


def _report(emit, prefix, handles, wall, eng):
    agg = eng.aggregate_stats()
    stats = [h.stats() for h in handles]
    n_tok = sum(s["n_generated"] for s in stats)
    ttft = np.array([s["ttft_s"] for s in stats])
    tpot = np.array([s["tpot_s"] for s in stats])
    tpot = tpot[np.isfinite(tpot)]
    spars = np.array([s["act_sparsity"] for s in stats])
    emit(f"{prefix}/requests", len(handles), "Poisson trace")
    emit(f"{prefix}/gen_tokens", n_tok, "total generated")
    emit(f"{prefix}/throughput_tok_s", n_tok / wall, "CPU interpret")
    emit(f"{prefix}/ttft_mean_ms", float(ttft.mean() * 1e3),
         "arrival->1st tok")
    emit(f"{prefix}/ttft_p95_ms", float(np.percentile(ttft, 95) * 1e3), "")
    emit(f"{prefix}/tpot_mean_ms", float(tpot.mean() * 1e3),
         "inter-token latency")
    # histogram-estimated percentiles from the metrics registry — the
    # same numbers a production scrape would see (bucket-interpolated,
    # so coarser than the exact per-request arrays above)
    r = eng.obs.registry
    for hname, key in (("serving_ttft_seconds", "ttft"),
                       ("serving_tpot_seconds", "tpot")):
        hist = r.get(hname)
        for q in (50, 99):
            p = hist.percentile(q)
            emit(f"{prefix}/{key}_p{q}_ms", float(p * 1e3),
                 f"registry histogram estimate, {hist.count()} samples")
    emit(f"{prefix}/act_sparsity_pct", float(spars.mean() * 100),
         "decode-time MSB4 sub-precision sparsity")
    if "wire_compression_pct" in agg:
        emit(f"{prefix}/wire_compression_pct", agg["wire_compression_pct"],
             "MEASURED packed-wire activation bytes saved vs dense int8")
        emit(f"{prefix}/wire_bytes_per_token",
             float(sum(agg["layer_wire_bytes_per_token"])),
             "measured bytes/token, inter-layer hidden stream, all layers")
    emit(f"{prefix}/engine_steps", agg["steps"], "continuous-batching steps")
    emit(f"{prefix}/pool_evictions", agg["pool_evictions"],
         "preemptions under page pressure")
    # compiled-HLO attribution joined with measured step times
    # (aggregate_stats above refreshed the gauges, so these are current)
    if eng._attr is not None:
        for phase in eng._attr.phases():
            emit(f"{prefix}/attr_{phase}_flops_per_step",
                 r.value("serving_step_attr_flops", phase=phase),
                 "dot FLOPs per engine step, compiled HLO")
            emit(f"{prefix}/attr_{phase}_hbm_bytes_per_step",
                 r.value("serving_step_attr_hbm_bytes", phase=phase),
                 "operand+result bytes per engine step, compiled HLO")
            emit(f"{prefix}/roofline_{phase}_compute_util",
                 r.value("serving_roofline_compute_util_ratio",
                         phase=phase),
                 "achieved FLOP/s vs HardwareConfig.peak_flops")
            emit(f"{prefix}/roofline_{phase}_memory_util",
                 r.value("serving_roofline_memory_util_ratio",
                         phase=phase),
                 "achieved HBM bytes/s vs HardwareConfig.hbm_bw")
            emit(f"{prefix}/drift_{phase}_latency_ratio",
                 r.value("serving_costmodel_latency_drift_ratio",
                         phase=phase),
                 "measured step s / costmodel.phase_cost prediction")
        emit(f"{prefix}/drift_wire_ratio",
             r.value("serving_costmodel_wire_drift_ratio"),
             "measured wire bytes/token / Eq.1 prediction (~1.0)")
    if eng.slo is not None:
        emit(f"{prefix}/slo_violations",
             sum(eng.slo.violations().values()),
             "edge-triggered SLO violation events across all SLOs")
    return float(tpot.mean() * 1e3) if len(tpot) else float("nan")


def run(emit, n_requests: int = 8, rate_hz: float = 2.0, seed: int = 0,
        spec_gamma: int = 0, mesh=None, slos=None):
    """Run the bench; returns {prefix: engine} for artifact export.

    ``slos`` — list of ``repro.obs.slo.SLO``; every engine gets its own
    monitor (fresh windows), and each prefix reports its violation
    count. SLO objects are stateless declarations, safe to share.
    """
    cfg = BENCH_CFG
    params = draft_friendly_params(cfg, seed=seed)
    qparams = quantize_model_params(
        params, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)
    trace = _poisson_trace(np.random.default_rng(seed), n_requests, rate_hz)

    engines = {}
    eng = _make_engine(cfg, qparams, 0, slos=slos)
    engines["serving"] = eng
    handles, wall = _drive(eng, trace)
    base_tpot = _report(emit, "serving", handles, wall, eng)

    # KV2 precision ladder: its own long-context config + trace
    # (unsharded by design — the ladder's host bookkeeping is single-pool)
    _run_kv2_ladder(emit, engines, seed)

    jmesh = None
    if mesh is not None:
        from repro.launch.mesh import make_smoke_mesh
        jmesh = make_smoke_mesh(data=mesh[0], model=mesh[1])
        meng = _make_engine(cfg, qparams, 0, mesh=jmesh, slos=slos)
        engines["serving_mesh"] = meng
        mesh_handles, mesh_wall = _drive(meng, trace)
        _report(emit, "serving_mesh", mesh_handles, mesh_wall, meng)
        match = all(hb.out_tokens == hm.out_tokens
                    for hb, hm in zip(handles, mesh_handles))
        emit("serving_mesh/tokens_match_single_device", int(match),
             f"sharded {mesh[0]}x{mesh[1]} greedy stream byte-identical "
             f"to the single-device engine")

    if spec_gamma <= 0:
        return engines
    spec_eng = _make_engine(cfg, qparams, spec_gamma, mesh=jmesh,
                            slos=slos)
    engines["serving_spec"] = spec_eng
    spec_handles, spec_wall = _drive(spec_eng, trace)
    agg = spec_eng.aggregate_stats()
    spec_tpot = _report(emit, "serving_spec", spec_handles, spec_wall,
                        spec_eng)
    emit("serving_spec/gamma", spec_gamma, "draft tokens per verify cycle")
    emit("serving_spec/acceptance_rate",
         agg.get("spec_acceptance_rate", float("nan")),
         "LSB4-only drafts accepted by full-precision verify")
    emit("serving_spec/tokens_per_step",
         agg.get("spec_tokens_per_step", float("nan")),
         "emitted tokens per draft+verify cycle (incl. correction)")
    emit("serving_spec/tpot_vs_base",
         spec_tpot / base_tpot if base_tpot else float("nan"),
         "spec TPOT / baseline TPOT on the same trace (<1 = faster)")
    match = all(hb.out_tokens == hs.out_tokens
                for hb, hs in zip(handles, spec_handles))
    emit("serving_spec/tokens_match_baseline", int(match),
         "greedy spec stream byte-identical to non-speculative engine")
    return engines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate (req/s of virtual time)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="also run the self-speculative engine with this "
                         "draft window on the same trace")
    ap.add_argument("--mesh", default="",
                    help="DATA,MODEL: also run the mesh-sharded engine "
                         "on the same trace and assert its greedy stream "
                         "matches the single-device engine (needs "
                         "data*model jax devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--slo", default="",
                    help="comma-separated SLO specs watched by every "
                         "engine (e.g. 'ttft:p95<5,queue_depth:p50<16'); "
                         "each prefix reports its violation count as "
                         "<prefix>/slo_violations")
    ap.add_argument("--slo-fail", action="store_true",
                    help="exit nonzero if any SLO fired on any engine")
    ap.add_argument("--history", default="",
                    help="append this run's provenance-stamped result to "
                         "the given perf-history JSONL (benchmarks/"
                         "perf_history.py schema)")
    ap.add_argument("--json", default="",
                    help="also write {meta, metrics} to this path — the "
                         "machine-readable result the CI regression gate "
                         "compares against benchmarks/baselines/"
                         "serving.json (benchmarks/check_regression.py)")
    ap.add_argument("--metrics-out", default="",
                    help="write each engine's metrics-registry snapshot "
                         "(JSON, {prefix: snapshot}) to this path")
    ap.add_argument("--trace-out", default="",
                    help="write the base engine's Chrome trace-event "
                         "JSON here — load in Perfetto / chrome://tracing")
    args = ap.parse_args()
    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.split(","))
        mesh = (d, m)

    records = {}

    def emit(name, value, desc):
        records[name] = float(value)
        print(f"{name},{value:.6g},{desc}", flush=True)

    from repro.obs.slo import parse_slo_list
    slos = parse_slo_list(args.slo)

    engines = run(emit, n_requests=args.requests, rate_hz=args.rate,
                  seed=args.seed, spec_gamma=args.spec_gamma, mesh=mesh,
                  slos=slos)

    for pfx, eng in engines.items():
        if eng.slo is None:
            continue
        for rep in eng.slo.report():
            state = "VIOLATING" if rep["violating"] else "ok"
            print(f"# {pfx} SLO {rep['slo']}: p{rep['percentile']:g} = "
                  f"{rep['value']:.4g} {rep['unit']} (target "
                  f"{rep['target']:g}) [{state}], "
                  f"{rep['violations']} violation(s)", flush=True)

    # stream-match metrics are hard invariants, not observations: the CI
    # smoke steps rely on a nonzero exit when equivalence breaks
    broken = [k for k, v in records.items()
              if k.endswith(("tokens_match_baseline",
                             "tokens_match_single_device",
                             "tokens_match_no_demotion")) and v != 1.0]
    # the ladder must genuinely reclaim KV HBM on the long-context
    # config — a silent demotion-policy regression fails the bench
    reclaimed = records.get("serving_kv2/hbm_reclaimed_pct")
    if reclaimed is not None and reclaimed < 25.0:
        broken.append(
            f"serving_kv2/hbm_reclaimed_pct={reclaimed:.1f} < 25")

    payload = None
    if args.json or args.history:
        from benchmarks.common import provenance_meta
        payload = {
            "meta": {"bench": "bench_serving", "config": BENCH_CFG.name,
                     "requests": args.requests, "rate_hz": args.rate,
                     "seed": args.seed, "spec_gamma": args.spec_gamma,
                     "mesh": list(mesh) if mesh else None,
                     "slo": args.slo or None,
                     **provenance_meta(BENCH_CFG)},
            "metrics": records,
        }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", flush=True)
    if args.history:
        from benchmarks.perf_history import append_record
        append_record(args.history, payload)
        print(f"appended to {args.history}", flush=True)

    if args.metrics_out:
        snaps = {pfx: eng.metrics_snapshot()
                 for pfx, eng in engines.items()}
        with open(args.metrics_out, "w") as f:
            json.dump(snaps, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.metrics_out}", flush=True)
    if args.trace_out:
        engines["serving"].obs.tracer.export_chrome(args.trace_out)
        print(f"wrote {args.trace_out}", flush=True)

    if broken:
        raise SystemExit(f"token-stream equivalence FAILED: {broken}")
    if args.slo_fail:
        fired = {pfx: eng.slo.violations()
                 for pfx, eng in engines.items()
                 if eng.slo is not None
                 and any(eng.slo.violations().values())}
        if fired:
            raise SystemExit(f"SLO violations: {fired}")


if __name__ == "__main__":
    main()
