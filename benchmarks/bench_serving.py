"""Serving-engine benchmark: continuous batching under a Poisson trace.

Drives the paged-pool engine with a Poisson request-arrival process
(exponential inter-arrival gaps, mixed prompt/generation lengths) and
reports the serving quantities the paper's system story turns on:
generation throughput, TTFT and TPOT distributions, achieved decode-time
MSB4 sub-precision sparsity, and pool pressure (evictions). Timings are
CPU interpret-mode — structural comparison only, not TPU numbers.

    PYTHONPATH=src python -m benchmarks.bench_serving          # smoke
    PYTHONPATH=src python -m benchmarks.bench_serving --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qlinear import quantize_model_params
from repro.models.schema import init_params
from repro.models.schema_builder import build_schema
from repro.serving import Engine, PoolConfig, SamplingParams, SchedulerConfig

BENCH_CFG = ModelConfig(
    name="bench-serve-2l", family="transformer", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
    rope_theta=10_000.0)


def _poisson_trace(rng: np.random.Generator, n: int, rate_hz: float):
    """[(arrival_offset_s, prompt, max_new), ...] sorted by arrival."""
    t = 0.0
    out = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate_hz)
        plen = int(rng.integers(8, 48))
        gen = int(rng.integers(4, 12))
        out.append((t, rng.integers(0, BENCH_CFG.vocab, plen).tolist(), gen))
    return out


def run(emit, n_requests: int = 8, rate_hz: float = 2.0,
        seed: int = 0) -> None:
    cfg = BENCH_CFG
    params = init_params(build_schema(cfg), jax.random.PRNGKey(seed))
    qparams = quantize_model_params(
        params, w_bits=4, k_percent=50.0, clip_l=-8.0, clip_h=23.0,
        mode="sparqle", enable_clipping=True, tile_k=16)
    eng = Engine(
        cfg, qparams,
        pool_config=PoolConfig(n_pages=48, page_size=16),
        sched_config=SchedulerConfig(max_decode_batch=8, token_budget=96,
                                     prefill_chunk=32,
                                     max_pages_per_seq=8))

    trace = _poisson_trace(np.random.default_rng(seed), n_requests, rate_hz)
    handles = []
    t0 = time.monotonic()
    i = 0
    # open-loop: submit once wall-clock passes each Poisson arrival,
    # stepping the engine in between (decodes keep flowing)
    while i < len(trace) or eng.sched.has_work():
        now = time.monotonic() - t0
        while i < len(trace) and trace[i][0] <= now:
            arr, prompt, gen = trace[i]
            handles.append(eng.submit(
                prompt, SamplingParams(max_new_tokens=gen)))
            i += 1
        if eng.sched.has_work():
            eng.step()
        elif i < len(trace):
            time.sleep(min(0.01, trace[i][0] - now))
    wall = time.monotonic() - t0

    stats = [h.stats() for h in handles]
    n_tok = sum(s["n_generated"] for s in stats)
    ttft = np.array([s["ttft_s"] for s in stats])
    tpot = np.array([s["tpot_s"] for s in stats])
    tpot = tpot[np.isfinite(tpot)]
    spars = np.array([s["act_sparsity"] for s in stats])
    agg = eng.aggregate_stats()

    emit("serving/requests", len(handles), "Poisson trace")
    emit("serving/gen_tokens", n_tok, "total generated")
    emit("serving/throughput_tok_s", n_tok / wall, "CPU interpret")
    emit("serving/ttft_mean_ms", float(ttft.mean() * 1e3), "arrival->1st tok")
    emit("serving/ttft_p95_ms", float(np.percentile(ttft, 95) * 1e3), "")
    emit("serving/tpot_mean_ms", float(tpot.mean() * 1e3),
         "inter-token latency")
    emit("serving/act_sparsity_pct", float(spars.mean() * 100),
         "decode-time MSB4 sub-precision sparsity")
    if "wire_compression_pct" in agg:
        emit("serving/wire_compression_pct", agg["wire_compression_pct"],
             "MEASURED packed-wire activation bytes saved vs dense int8")
        emit("serving/wire_bytes_per_token",
             float(sum(agg["layer_wire_bytes_per_token"])),
             "measured bytes/token, inter-layer hidden stream, all layers")
    emit("serving/engine_steps", agg["steps"], "continuous-batching steps")
    emit("serving/pool_evictions", agg["pool_evictions"],
         "preemptions under page pressure")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(lambda n, v, d: print(f"{n},{v:.6g},{d}", flush=True),
        n_requests=args.requests, rate_hz=args.rate, seed=args.seed)


if __name__ == "__main__":
    main()
