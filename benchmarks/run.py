"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived-from`` CSV rows. Modules:

  * bench_compression — §3.1 / Fig. 2 / Eq. 1-2 statistics
  * bench_costmodel   — Fig. 6 + §5.2 accelerator model vs paper claims
  * bench_k_sweep     — Fig. 7 accuracy/sparsity tradeoff across k
  * bench_layerwise   — Fig. 8 per-projection latency trend
  * bench_accuracy    — Table 2 analogue on the self-trained LM
  * bench_kernels     — tile-skip co-design validation + kernel timings
  * bench_serving     — continuous-batching engine under a Poisson trace

Roofline (deliverable g) is separate: ``python -m benchmarks.roofline``
reads the dry-run artifacts.
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (bench_accuracy, bench_compression, bench_costmodel,
                        bench_k_sweep, bench_kernels, bench_layerwise,
                        bench_serving)

MODULES = [
    ("compression", bench_compression.run),
    ("costmodel", lambda emit: bench_costmodel.run(emit, False)),
    ("k_sweep", bench_k_sweep.run),
    ("layerwise", bench_layerwise.run),
    ("accuracy", bench_accuracy.run),
    ("kernels", bench_kernels.run),
    ("serving", bench_serving.run),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,value,derived_from")
    failures = 0
    for name, fn in MODULES:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            fn(lambda n, v, d: print(f"{n},{v:.6g},{d}", flush=True))
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
