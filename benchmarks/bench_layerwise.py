"""Paper Fig. 8: layerwise latency-reduction trend.

Measures per-projection-site MSB4 sparsity on the trained benchmark LM,
feeds those per-site sparsities into the accelerator cost model
(per_layer_s), and reports the latency reduction per projection class.
The paper's claim to reproduce: o_proj / down_proj (SiLU-fed, more
Laplacian-like inputs) gain more than q/k/v projections.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import BENCH_DATA, probe_linear_inputs, \
    trained_smoke_model
from repro.core.costmodel import (HardwareConfig, LinearShape,
                                  linear_cost)
from repro.core.sparqle import subprecision_sparsity
from repro.data.pipeline import SyntheticLM


def run(emit) -> None:
    cfg, params = trained_smoke_model()
    data = SyntheticLM(BENCH_DATA)
    batch = {"tokens": jnp.asarray(data.batch_at(10_000)["tokens"])}
    sites = dict()
    for name, q8 in probe_linear_inputs(cfg, params, batch):
        sites[name] = float(subprecision_sparsity(q8))

    site_to_projs = {
        "q_proj_in": ("q_proj", "k_proj", "v_proj"),
        "o_proj_in": ("o_proj",),
        "gate_up_in": ("gate_proj", "up_proj"),
        "down_proj_in": ("down_proj",),
    }
    hw = HardwareConfig()
    d, f = 4096, 11008
    dims = {"q_proj": (d, d), "k_proj": (d, d), "v_proj": (d, d),
            "o_proj": (d, d), "gate_proj": (d, f), "up_proj": (d, f),
            "down_proj": (f, d)}
    m = 2048
    reductions = {}
    for site, projs in site_to_projs.items():
        s = sites[site]
        for pj in projs:
            k_, n_ = dims[pj]
            shape = LinearShape(pj, m, k_, n_, w_bits=4, s=s)
            base = linear_cost(shape, hw, sparqle=False)
            spq = linear_cost(shape, hw, sparqle=True)
            red = (1 - spq.cycles / base.cycles) * 100
            reductions[pj] = red
            emit(f"layerwise/latency_reduction_{pj}", red,
                 f"input sparsity {s*100:.1f}%")

    # Fig. 8 trend: SiLU-fed down_proj gains the most, o_proj above qkv
    emit("layerwise/trend_down_gt_q",
         reductions["down_proj"] - reductions["q_proj"],
         "pp: positive reproduces the paper's Fig. 8 ordering")


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v:.4g},{d}"))
