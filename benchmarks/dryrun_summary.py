"""Regenerate the EXPERIMENTS.md §Dry-run appendix from runs/dryrun/.

    PYTHONPATH=src:. python -m benchmarks.dryrun_summary [--mesh all]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RUNS = os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun")


def table(mesh: str) -> str:
    rows = [f"### {mesh}\n",
            "| cell | flops/dev | HLO coll B/dev | arg+temp GiB/dev | "
            "arg+out GiB/dev | compile s |\n",
            "|---|---|---|---|---|---|\n"]
    for path in sorted(glob.glob(os.path.join(RUNS, mesh, "*.json"))):
        r = json.load(open(path))
        if "error" in r:
            rows.append(f"| {os.path.basename(path)} | ERROR |\n")
            continue
        m = r["memory"]
        rows.append(
            f"| {r['arch']}/{r['shape']} | {r['flops_hlo']:.2e} | "
            f"{r['collective_bytes'].get('total', 0):.2e} | "
            f"{(m['argument_size_b'] + m['temp_size_b'])/2**30:.2f} | "
            f"{(m['argument_size_b'] + m['output_size_b'])/2**30:.2f} | "
            f"{r['compile_s']} |\n")
    return "".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="all")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    meshes = (sorted(os.listdir(RUNS)) if args.mesh == "all"
              else [args.mesh])
    out = "\n".join(table(m) for m in meshes if
                    os.path.isdir(os.path.join(RUNS, m)))
    print(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(out)


if __name__ == "__main__":
    main()
