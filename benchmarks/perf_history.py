"""Persistent serving-perf history: append-only JSONL + trajectory check.

Every gated bench run leaves one provenance-stamped line in
``benchmarks/history/perf_history.jsonl`` — the {meta, metrics} payload
``bench_serving --json`` writes, plus a record timestamp — so the
repo accumulates a perf trajectory instead of only a pass/fail against
the latest committed baseline. CI appends the current run and then runs
the ``check`` subcommand, which fails on:

  * structural rot — unparseable lines, records missing provenance
    (git_sha / jax_version / config_hash) or metrics;
  * trajectory collapse — the newest record's key metric (default
    ``serving/throughput_tok_s``) falling below ``1/factor`` of the
    median of the prior runs (factor defaults to 5.0: CI machines vary
    wildly, so only order-of-magnitude cliffs fail; the committed
    ``check_regression`` gate stays the tight same-machine check).

Usage:
    python benchmarks/perf_history.py append --result serving_bench.json \
        --history benchmarks/history/perf_history.jsonl
    python benchmarks/perf_history.py check \
        --history benchmarks/history/perf_history.jsonl
    python benchmarks/perf_history.py show --history ... [--key ...]

``bench_serving --history PATH`` appends directly, skipping the
intermediate file.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

DEFAULT_HISTORY = os.path.join(os.path.dirname(__file__), "history",
                               "perf_history.jsonl")
DEFAULT_KEY = "serving/throughput_tok_s"
REQUIRED_META = ("bench", "git_sha", "jax_version", "config_hash")


def append_record(path: str, payload: Dict) -> Dict:
    """Append one bench result ({meta, metrics}) as a history line."""
    problems = _record_problems(payload, where="payload")
    if problems:
        raise ValueError("refusing to append a malformed record: "
                         + "; ".join(problems))
    rec = {"recorded_unix": time.time(), "meta": payload["meta"],
           "metrics": payload["metrics"]}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
    return rec


def load_history(path: str) -> List[Dict]:
    """Parse every line; raises ValueError naming the first bad line."""
    records = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            if not line.strip():
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: unparseable history line "
                                 f"({e})") from e
    return records


def _record_problems(rec: Dict, where: str) -> List[str]:
    out = []
    meta = rec.get("meta")
    if not isinstance(meta, dict):
        return [f"{where}: no meta block"]
    for k in REQUIRED_META:
        if not meta.get(k):
            out.append(f"{where}: meta.{k} missing/empty")
    metrics = rec.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        out.append(f"{where}: no metrics")
    else:
        bad = [k for k, v in metrics.items()
               if not isinstance(v, (int, float))]
        if bad:
            out.append(f"{where}: non-numeric metrics {bad[:3]}")
    return out


def check_history(path: str, key: str = DEFAULT_KEY,
                  factor: float = 5.0) -> List[str]:
    """Validate the whole trajectory; returns problems (empty = pass)."""
    try:
        records = load_history(path)
    except (OSError, ValueError) as e:
        return [str(e)]
    if not records:
        return [f"{path}: empty history (seed it with one append)"]
    problems = []
    for i, rec in enumerate(records, 1):
        problems += _record_problems(rec, where=f"record {i}")
    times = [r.get("recorded_unix", 0) for r in records]
    if times != sorted(times):
        problems.append("records are not in append (time) order")
    vals = [r["metrics"][key] for r in records
            if isinstance(r.get("metrics"), dict)
            and isinstance(r["metrics"].get(key), (int, float))]
    if not vals:
        problems.append(f"no record carries trajectory key {key!r}")
    elif len(vals) >= 2:
        prior = sorted(vals[:-1])
        median = prior[len(prior) // 2]
        if vals[-1] < median / factor:
            problems.append(
                f"trajectory collapse: latest {key}={vals[-1]:.6g} is "
                f"<1/{factor:g} of the prior median {median:.6g}")
    return problems


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_app = sub.add_parser("append", help="append one bench result")
    p_app.add_argument("--result", required=True,
                       help="bench_serving --json output file")
    p_app.add_argument("--history", default=DEFAULT_HISTORY)
    p_chk = sub.add_parser("check", help="validate the trajectory")
    p_chk.add_argument("--history", default=DEFAULT_HISTORY)
    p_chk.add_argument("--key", default=DEFAULT_KEY)
    p_chk.add_argument("--factor", type=float, default=5.0)
    p_show = sub.add_parser("show", help="print the trajectory of a key")
    p_show.add_argument("--history", default=DEFAULT_HISTORY)
    p_show.add_argument("--key", default=DEFAULT_KEY)
    args = ap.parse_args(argv)

    if args.cmd == "append":
        with open(args.result) as f:
            payload = json.load(f)
        rec = append_record(args.history, payload)
        print(f"appended {rec['meta'].get('bench')} @ "
              f"{rec['meta'].get('git_sha', '')[:12]} to {args.history}")
    elif args.cmd == "check":
        problems = check_history(args.history, key=args.key,
                                 factor=args.factor)
        if problems:
            for p in problems:
                print(f"FAIL: {p}")
            raise SystemExit(1)
        n = len(load_history(args.history))
        print(f"perf history OK: {n} record(s), key {args.key!r}")
    elif args.cmd == "show":
        for rec in load_history(args.history):
            m = rec.get("meta", {})
            v = rec.get("metrics", {}).get(args.key)
            print(f"{m.get('git_sha', 'unknown')[:12]}  "
                  f"jax={m.get('jax_version', '?')}  "
                  f"{args.key}={v}")


if __name__ == "__main__":
    main()
