"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (runs/dryrun/<mesh>/*.json — all values per
device) and derives, per cell:

    compute term    = HLO_dot_FLOPs_per_dev / peak_FLOPs        [s]
    memory term     = HLO_HBM_bytes_per_dev / HBM_bw            [s]
    collective term = collective_bytes_per_dev / link_bw        [s]

Hardware: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment constants). The dominant term is the structural bottleneck;
MODEL_FLOPS (6*N*D train / 2*N_active*D serving) over the compute peak
gives the useful-compute time, and

    roofline_fraction = useful_compute_time / dominant_term

is the MFU-style score reported in EXPERIMENTS.md §Perf.

Hardware peaks come from ``costmodel.HardwareConfig`` (peak_flops /
hbm_bw / link_bw — the same substrate the live attribution layer in
``repro.obs.attribution`` normalizes against), so the offline roofline
and the serving engine's ``serving_roofline_*`` gauges are computed
against one set of constants.

Usage::

    PYTHONPATH=src:. python -m benchmarks.roofline [--mesh singlepod] \
        [--md runs/roofline_singlepod.md]

    # serving mode: roofline the engine's attribution snapshot
    # (bench_serving --metrics-out metrics.json)
    PYTHONPATH=src:. python -m benchmarks.roofline --metrics metrics.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.configs.base import SHAPES
from repro.core.costmodel import HardwareConfig
from repro.models.registry import ARCHS
from repro.models.schema import param_count
from repro.models.schema_builder import build_schema

HW = HardwareConfig()        # TPU-v5e-class reference peaks
RUNS = os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun")


def _param_counts(cfg) -> Dict[str, float]:
    """(total, active) parameter counts. Active discounts routed experts
    by top_k/n_experts (the 6*N_active*D MoE convention)."""
    schema = build_schema(cfg)
    total = param_count(schema)
    if not cfg.n_experts:
        return {"total": total, "active": total}
    e, k = cfg.n_experts, cfg.top_k
    f = cfg.moe_d_ff or cfg.d_ff
    d = cfg.d_model
    n_moe_layers = cfg.n_layers - cfg.first_dense
    if cfg.family == "hybrid":
        n_moe_layers = cfg.n_layers // cfg.moe_every
    routed = n_moe_layers * e * (3 * d * f)
    active = total - routed + routed * (k / e)
    return {"total": total, "active": active}


def model_flops(arch: str, shape_name: str) -> float:
    """Global MODEL_FLOPS for one step of this cell (6ND / 2ND)."""
    cfg = ARCHS[arch]
    shp = SHAPES[shape_name]
    n = _param_counts(cfg)
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n["active"] * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n["active"] * tokens
    # decode: one token per sequence per step
    return 2.0 * n["active"] * shp.global_batch


def suggest(rec: dict, dominant: str) -> str:
    if dominant == "collective":
        top = rec.get("top_colls", [])
        what = top[0][1].split(" ")[0] if top else "collectives"
        return (f"dominated by {what} traffic — reduce FSDP regather "
                "(gather once per step, not per microbatch/layer) or "
                "switch the offending tensor's sharding")
    if dominant == "memory":
        return ("HBM-bound — fuse/shrink the dominant intermediate "
                "(KV-cache dequant streams, MoE dispatch buffers), or use "
                "true int4 packing to halve quantized streams")
    return ("compute-bound — raise MXU utilization: larger per-device "
            "tiles, drop redundant recompute (remat policy), or exploit "
            "the int8 2x MXU rate for the quantized dual-pass")


def analyze_mesh(mesh: str, hw: HardwareConfig = HW) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RUNS, mesh, "*.json"))):
        rec = json.load(open(path))
        if "error" in rec:
            continue
        arch, shape_name = rec["arch"], rec["shape"]
        n_dev = rec["n_devices"]
        t_comp = rec["flops_hlo"] / hw.peak_flops
        # HBM term: structural lower bound — every program argument is
        # read once and every output written once per step (params, opt
        # state, KV caches, batch). This is exact for decode (weight/cache
        # streaming dominates) and fusion-optimistic for train/prefill.
        # The op-level proxy (hbm_bytes_hlo) is kept as a pessimistic
        # diagnostic: the CPU backend fuses far less than TPU, so counting
        # per-op I/O over-states TPU HBM traffic by an order of magnitude.
        mem = rec["memory"]
        hbm_lb = mem["argument_size_b"] + mem["output_size_b"]
        t_mem = hbm_lb / hw.hbm_bw
        t_mem_diag = rec["hbm_bytes_hlo"] / hw.hbm_bw
        t_coll = rec["collective_bytes"].get("total", 0.0) / hw.link_bw
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(arch, shape_name)
        t_useful = mf / n_dev / hw.peak_flops
        frac = t_useful / max(terms.values()) if max(terms.values()) else 0
        rows.append({
            "arch": arch, "shape": shape_name, "mesh": mesh,
            "n_devices": n_dev,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_memory_diag_s": t_mem_diag,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf,
            "useful_ratio": mf / n_dev / max(rec["flops_hlo"], 1.0),
            "roofline_fraction": frac,
            "mem_per_dev_gib": (rec["memory"]["argument_size_b"] +
                                rec["memory"]["temp_size_b"]) / 2**30,
            "note": suggest(rec, dominant),
        })
    return rows


def _series_map(snap: dict, name: str, label: str) -> Dict[str, dict]:
    """{label value: series entry} for one metric of one snapshot."""
    m = snap.get(name)
    if not m:
        return {}
    return {s["labels"].get(label, ""): s for s in m.get("series", [])}


def analyze_snapshot(path: str, hw: HardwareConfig = HW) -> List[dict]:
    """Roofline the serving engine's attribution snapshot.

    ``path`` is a ``--metrics-out`` artifact: ``{prefix: snapshot}``
    (bench_serving) or one bare registry snapshot (serve.py). Each
    attributed phase with measured step times becomes one row with the
    same three terms as the dry-run mode, plus achieved utilization.
    """
    with open(path) as f:
        data = json.load(f)
    if "serving_step_attr_flops" in data:          # bare snapshot
        data = {"serving": data}
    rows = []
    for prefix in sorted(data):
        snap = data[prefix]
        flops = _series_map(snap, "serving_step_attr_flops", "phase")
        hbm = _series_map(snap, "serving_step_attr_hbm_bytes", "phase")
        tokens = _series_map(snap, "serving_step_attr_tokens", "phase")
        lat = _series_map(snap, "serving_step_seconds", "phase")
        coll = {}
        for s in (snap.get("serving_step_attr_coll_bytes") or
                  {"series": []})["series"]:
            if s["labels"].get("kind") == "total":
                coll[s["labels"]["phase"]] = s["value"]
        for phase in sorted(flops):
            t_comp = flops[phase]["value"] / hw.peak_flops
            t_mem = hbm[phase]["value"] / hw.hbm_bw
            t_coll = coll.get(phase, 0.0) / hw.link_bw
            terms = {"compute": t_comp, "memory": t_mem,
                     "collective": t_coll}
            dominant = max(terms, key=terms.get)
            row = {
                "arch": prefix, "shape": phase, "mesh": "serving",
                "n_devices": 1,
                "t_compute_s": t_comp, "t_memory_s": t_mem,
                "t_collective_s": t_coll, "dominant": dominant,
                "tokens_per_step": tokens.get(phase, {}).get("value"),
            }
            s = lat.get(phase)
            if s and s.get("count"):
                measured = s["sum"] / s["count"]
                row["measured_step_s"] = measured
                row["compute_util"] = (flops[phase]["value"] / measured
                                       / hw.peak_flops)
                row["memory_util"] = (hbm[phase]["value"] / measured
                                      / hw.hbm_bw)
                # roofline bound vs what the step actually took
                row["roofline_fraction"] = max(terms.values()) / measured
            rows.append(row)
    return rows


def snapshot_markdown(rows: List[dict]) -> str:
    hdr = ("| engine | phase | compute s | memory s | collective s | "
           "dominant | measured s | compute util | memory util |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        meas = r.get("measured_step_s")
        tail = ("- | - | - |" if meas is None else
                f"{meas:.3e} | {r['compute_util']:.2e} | "
                f"{r['memory_util']:.2e} |")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {tail}\n")
    return "".join(out)


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | roofline frac | mem/dev GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"**{r['roofline_fraction']:.3f}** | "
            f"{r['mem_per_dev_gib']:.2f} |\n")
    return "".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod")
    ap.add_argument("--metrics", default=None,
                    help="roofline a serving metrics snapshot (the "
                         "attribution artifact bench_serving/serve.py "
                         "--metrics-out writes) instead of the dry-run "
                         "trainer JSONs")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    if args.metrics:
        rows = analyze_snapshot(args.metrics)
        md = snapshot_markdown(rows)
        print(md)
    else:
        rows = analyze_mesh(args.mesh)
        md = to_markdown(rows)
        print(md)
        for r in rows:
            print(f"# {r['arch']}/{r['shape']}: {r['dominant']}-bound -> "
                  f"{r['note']}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(md)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
