"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (runs/dryrun/<mesh>/*.json — all values per
device) and derives, per cell:

    compute term    = HLO_dot_FLOPs_per_dev / peak_FLOPs        [s]
    memory term     = HLO_HBM_bytes_per_dev / HBM_bw            [s]
    collective term = collective_bytes_per_dev / link_bw        [s]

Hardware: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment constants). The dominant term is the structural bottleneck;
MODEL_FLOPS (6*N*D train / 2*N_active*D serving) over the compute peak
gives the useful-compute time, and

    roofline_fraction = useful_compute_time / dominant_term

is the MFU-style score reported in EXPERIMENTS.md §Perf.

Usage::

    PYTHONPATH=src:. python -m benchmarks.roofline [--mesh singlepod] \
        [--md runs/roofline_singlepod.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.configs.base import SHAPES
from repro.models.registry import ARCHS
from repro.models.schema import param_count
from repro.models.schema_builder import build_schema

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s/link
RUNS = os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun")


def _param_counts(cfg) -> Dict[str, float]:
    """(total, active) parameter counts. Active discounts routed experts
    by top_k/n_experts (the 6*N_active*D MoE convention)."""
    schema = build_schema(cfg)
    total = param_count(schema)
    if not cfg.n_experts:
        return {"total": total, "active": total}
    e, k = cfg.n_experts, cfg.top_k
    f = cfg.moe_d_ff or cfg.d_ff
    d = cfg.d_model
    n_moe_layers = cfg.n_layers - cfg.first_dense
    if cfg.family == "hybrid":
        n_moe_layers = cfg.n_layers // cfg.moe_every
    routed = n_moe_layers * e * (3 * d * f)
    active = total - routed + routed * (k / e)
    return {"total": total, "active": active}


def model_flops(arch: str, shape_name: str) -> float:
    """Global MODEL_FLOPS for one step of this cell (6ND / 2ND)."""
    cfg = ARCHS[arch]
    shp = SHAPES[shape_name]
    n = _param_counts(cfg)
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n["active"] * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n["active"] * tokens
    # decode: one token per sequence per step
    return 2.0 * n["active"] * shp.global_batch


def suggest(rec: dict, dominant: str) -> str:
    if dominant == "collective":
        top = rec.get("top_colls", [])
        what = top[0][1].split(" ")[0] if top else "collectives"
        return (f"dominated by {what} traffic — reduce FSDP regather "
                "(gather once per step, not per microbatch/layer) or "
                "switch the offending tensor's sharding")
    if dominant == "memory":
        return ("HBM-bound — fuse/shrink the dominant intermediate "
                "(KV-cache dequant streams, MoE dispatch buffers), or use "
                "true int4 packing to halve quantized streams")
    return ("compute-bound — raise MXU utilization: larger per-device "
            "tiles, drop redundant recompute (remat policy), or exploit "
            "the int8 2x MXU rate for the quantized dual-pass")


def analyze_mesh(mesh: str) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RUNS, mesh, "*.json"))):
        rec = json.load(open(path))
        if "error" in rec:
            continue
        arch, shape_name = rec["arch"], rec["shape"]
        n_dev = rec["n_devices"]
        t_comp = rec["flops_hlo"] / PEAK_FLOPS
        # HBM term: structural lower bound — every program argument is
        # read once and every output written once per step (params, opt
        # state, KV caches, batch). This is exact for decode (weight/cache
        # streaming dominates) and fusion-optimistic for train/prefill.
        # The op-level proxy (hbm_bytes_hlo) is kept as a pessimistic
        # diagnostic: the CPU backend fuses far less than TPU, so counting
        # per-op I/O over-states TPU HBM traffic by an order of magnitude.
        mem = rec["memory"]
        hbm_lb = mem["argument_size_b"] + mem["output_size_b"]
        t_mem = hbm_lb / HBM_BW
        t_mem_diag = rec["hbm_bytes_hlo"] / HBM_BW
        t_coll = rec["collective_bytes"].get("total", 0.0) / LINK_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(arch, shape_name)
        t_useful = mf / n_dev / PEAK_FLOPS
        frac = t_useful / max(terms.values()) if max(terms.values()) else 0
        rows.append({
            "arch": arch, "shape": shape_name, "mesh": mesh,
            "n_devices": n_dev,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_memory_diag_s": t_mem_diag,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf,
            "useful_ratio": mf / n_dev / max(rec["flops_hlo"], 1.0),
            "roofline_fraction": frac,
            "mem_per_dev_gib": (rec["memory"]["argument_size_b"] +
                                rec["memory"]["temp_size_b"]) / 2**30,
            "note": suggest(rec, dominant),
        })
    return rows


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | roofline frac | mem/dev GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"**{r['roofline_fraction']:.3f}** | "
            f"{r['mem_per_dev_gib']:.2f} |\n")
    return "".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = analyze_mesh(args.mesh)
    md = to_markdown(rows)
    print(md)
    for r in rows:
        print(f"# {r['arch']}/{r['shape']}: {r['dominant']}-bound -> "
              f"{r['note']}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(md)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
