"""Paper §3.1 / Fig. 2 / Eq. 1-2: sub-precision statistics on a real model.

Measures, on the trained benchmark LM:
  * natural MSB4 sparsity per projection site (the §3.1 observation —
    SiLU-gated down_proj inputs are the sparsest, q_proj inputs the least),
  * the zero-point-adjustment effect on SiLU-like activations,
  * Eq. 1 compression % and Eq. 2 ops-reduction % at measured sparsity,
  * MEASURED wire bytes of the real packed format (``core/packing.py``:
    LSB4 pairs + PBM words + compacted MSB stream) vs the Eq. 1
    analytical prediction, with the per-site gap — the two should agree
    to within the PBM-word/stream-byte rounding slack (< 2 %).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import BENCH_DATA, probe_linear_inputs, \
    trained_smoke_model
from repro.core.packing import decode_packed, encode_packed
from repro.core.quantize import quantize_activations
from repro.core.sparqle import (compression_percent, encoded_bytes,
                                ops_reduction_percent, subprecision_sparsity)
from repro.data.pipeline import SyntheticLM


def run(emit) -> None:
    cfg, params = trained_smoke_model()
    data = SyntheticLM(BENCH_DATA)
    batch = {"tokens": jnp.asarray(data.batch_at(10_000)["tokens"])}

    sites = probe_linear_inputs(cfg, params, batch)
    s_by_site = {}
    for name, q8 in sites:
        s = float(subprecision_sparsity(q8))
        s_by_site[name] = s
        emit(f"compression/sparsity_{name}", s * 100, "% MSB4==0")
        emit(f"compression/eq1_{name}",
             float(compression_percent(s)), "% bytes saved (Eq.1)")
        emit(f"compression/eq2_{name}",
             float(ops_reduction_percent(s)), "% int4 ops skipped (Eq.2)")
        n = q8.size
        predicted = encoded_bytes(q8.shape, s)
        emit(f"compression/wire_bytes_predicted_{name}",
             predicted / n, "B/elem, Eq.1 analytical, vs 1.0 dense")
        # the real packed codec: measure the bytes, verify exactness
        pa = encode_packed(q8)
        assert bool(jnp.all(decode_packed(pa) == q8)), name
        measured = float(pa.wire_bytes())
        emit(f"compression/wire_bytes_measured_{name}",
             measured / n, "B/elem, packed wire format, vs 1.0 dense")
        gap = (measured - predicted) / predicted * 100
        emit(f"compression/wire_gap_{name}", gap,
             "% measured vs Eq.1 predicted (PBM-word rounding slack)")

    # the paper's §3.1 ordering claim: SiLU-gated site sparser than q input
    emit("compression/silu_vs_q_gap",
         (s_by_site["down_proj_in"] - s_by_site["q_proj_in"]) * 100,
         "pp (paper reports 89 vs 32 on Llama3)")

    # zero-point adjustment on a SiLU output (paper §3.1)
    import jax
    x = jax.nn.silu(jax.random.normal(jax.random.PRNGKey(0),
                                      (4096, 256)) * 2.0)
    s_sym = float(subprecision_sparsity(
        quantize_activations(x, zero_point=False).q))
    s_zp = float(subprecision_sparsity(
        quantize_activations(x, zero_point=True).q))
    emit("compression/zero_point_gain", (s_zp - s_sym) * 100,
         f"pp sparsity from zero-point shift ({s_sym*100:.1f} -> "
         f"{s_zp*100:.1f})")


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v:.4g},{d}"))
