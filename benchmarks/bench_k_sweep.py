"""Paper Fig. 7: accuracy / sub-precision-sparsity tradeoff across k.

Sweeps k (the fraction of least-important activation columns eligible for
clipping) from 0 to 100 on the trained benchmark LM: at each k the model
is quantized W4A8 + clipped, and we measure (a) achieved MSB4 sparsity of
the projection inputs, (b) held-out perplexity. The paper's claims to
reproduce: sparsity increases monotonically with k; accuracy degrades
gracefully; SPARQLe's accuracy stays between the W4A8 and W4A4 baselines.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (BENCH_DATA, eval_ppl, probe_linear_inputs,
                               trained_smoke_model)
from repro.core.qlinear import quantize_model_params
from repro.core.clipping import apply_clipping, importance_mask_tile_aligned
from repro.core.sparqle import subprecision_sparsity
from repro.data.pipeline import SyntheticLM

KS = (0.0, 25.0, 50.0, 75.0, 100.0)
CLIP_L, CLIP_H = -16.0, 31.0
TILE_K = 16


def run(emit) -> None:
    cfg, params = trained_smoke_model()
    data = SyntheticLM(BENCH_DATA)
    batch = {"tokens": jnp.asarray(data.batch_at(10_000)["tokens"])}

    ppl_float = eval_ppl(cfg, params)
    emit("k_sweep/ppl_float", ppl_float, "fp32 reference")

    # W4A8 / W4A4 baselines (no clipping)
    qp8 = quantize_model_params(params, w_bits=4, enable_clipping=False)
    ppl_w4a8 = eval_ppl(cfg, qp8)
    emit("k_sweep/ppl_w4a8", ppl_w4a8, "upper accuracy anchor")

    import repro.core.quantize as Q
    orig = Q.quantize_activations

    def a4(x, bits=8, per_token=True, zero_point=False):
        return orig(x, bits=4, per_token=per_token, zero_point=zero_point)

    Q.quantize_activations = a4
    try:
        import repro.core.qlinear as QL
        QL.quantize_activations = a4
        ppl_w4a4 = eval_ppl(cfg, qp8)
    finally:
        Q.quantize_activations = orig
        import repro.core.qlinear as QL
        QL.quantize_activations = orig
    emit("k_sweep/ppl_w4a4", ppl_w4a4, "lower accuracy anchor")

    sites = probe_linear_inputs(cfg, params, batch)
    p0 = params["stages"]["s0"]["p0"]
    site_w = {"q_proj_in": p0["wq"][0], "o_proj_in": p0["wo"][0],
              "gate_up_in": p0["w_gate"][0],
              "down_proj_in": p0["w_down"][0]}

    prev_s = -1.0
    for k in KS:
        qp = quantize_model_params(
            params, w_bits=4, k_percent=k, clip_l=CLIP_L, clip_h=CLIP_H,
            enable_clipping=k > 0, tile_k=TILE_K)
        ppl = eval_ppl(cfg, qp)
        # sparsity: clip each probed site with its own mask, measure
        ss = []
        for name, q8 in sites:
            mask = importance_mask_tile_aligned(
                jnp.asarray(site_w[name]), k, TILE_K)
            qc = apply_clipping(q8, mask, int(CLIP_L), int(CLIP_H)) \
                if k > 0 else q8
            ss.append(float(subprecision_sparsity(qc)))
        s_mean = sum(ss) / len(ss)
        emit(f"k_sweep/sparsity_k{int(k)}", s_mean * 100, "% MSB4==0")
        emit(f"k_sweep/ppl_k{int(k)}", ppl,
             f"between W4A8 {ppl_w4a8:.2f} and W4A4 {ppl_w4a4:.2f}")
        assert s_mean >= prev_s - 1e-6, "sparsity must rise with k"
        prev_s = s_mean


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v:.4g},{d}"))
