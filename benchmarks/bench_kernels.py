"""Kernel-level accounting: tile-skip co-design validation + wall-time.

The TPU adaptation converts element-level sub-precision sparsity into
VMEM-tile skipping (@pl.when). This benchmark validates the co-design
claim of DESIGN.md §2: with tile-ALIGNED column clipping, the fraction of
skippable (bm x bk) MSB4 tiles approaches the element sparsity, while
unaligned clipping at identical element sparsity skips ~nothing. Also
reports interpret-mode wall-times (structural only — CPU interpret is not
TPU timing) and the analytic ops reduction.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.clipping import (apply_clipping, importance_mask,
                                 importance_mask_tile_aligned)
from repro.core.sparqle import (encode, ops_reduction_percent,
                                subprecision_sparsity, tile_sparsity)
from repro.kernels.ops import dense_quant_linear, sparqle_linear
from repro.core.quantize import quantize_weights

BM = BK = 128


def run(emit) -> None:
    key = jax.random.PRNGKey(0)
    m, k, n = 512, 1024, 512
    # activations with realistic near-zero concentration
    x = (jax.random.normal(key, (m, k)) *
         (10 + 50 * (jax.random.uniform(jax.random.PRNGKey(1), (1, k)) <
                     0.2))).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / 1.0), -128, 127).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.05

    for aligned in (False, True):
        if aligned:
            cmask = importance_mask_tile_aligned(w, 50.0, BK)
        else:
            cmask = importance_mask(w, 50.0)
        qc = apply_clipping(q, cmask, -128, 127)  # clip every masked col
        s_elem = float(subprecision_sparsity(qc))
        a = encode(qc)
        s_tile = float(tile_sparsity(a.pbm, BM, BK))
        tag = "aligned" if aligned else "unaligned"
        emit(f"kernels/elem_sparsity_{tag}", s_elem * 100, "%")
        emit(f"kernels/tile_skip_{tag}", s_tile * 100,
             "% of MSB4 tiles skipped by @pl.when")
        emit(f"kernels/ops_reduction_elem_{tag}",
             float(ops_reduction_percent(s_elem)), "Eq.2 at element level")
        emit(f"kernels/ops_reduction_tile_{tag}", s_tile / 2 * 100,
             "realized on the MXU (tile granular)")

    # wall time (interpret mode; structural comparison only)
    wq = quantize_weights(w, bits=4, axis=0)
    xf = x * 0.01
    for name, fn in (("sparqle", lambda: sparqle_linear(xf, wq)),
                     ("dense", lambda: dense_quant_linear(xf, wq))):
        fn()  # compile
        t0 = time.time()
        for _ in range(3):
            fn().block_until_ready()
        emit(f"kernels/wall_ms_{name}", (time.time() - t0) / 3 * 1e3,
             "CPU interpret-mode, NOT TPU timing")


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v:.4g},{d}"))
