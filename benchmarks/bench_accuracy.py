"""Paper Table 2 analogue: end-to-end accuracy of SPARQLe serving.

No pretrained Llama/BitNet checkpoints exist offline, so the Table-2
experiment is reproduced in *structure* on the self-trained benchmark LM:
float reference vs W4A8 baseline vs SPARQLe (W4A8 + clipping at the
calibrated global (l, h)) vs the W4A4 baseline, on held-out synthetic
perplexity. Claims to reproduce: (1) SPARQLe degrades only mildly vs the
W4A8 baseline; (2) SPARQLe stays strictly better than W4A4; (3) the
global calibration sweep picks sane constants.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (BENCH_DATA, eval_ppl, probe_linear_inputs,
                               trained_smoke_model)
from repro.core.clipping import (apply_clipping, global_calibrate,
                                 importance_mask_tile_aligned)
from repro.core.qlinear import quantize_model_params
from repro.core.sparqle import subprecision_sparsity
from repro.data.pipeline import SyntheticLM


def run(emit) -> None:
    cfg, params = trained_smoke_model()
    data = SyntheticLM(BENCH_DATA)
    batch = {"tokens": jnp.asarray(data.batch_at(10_000)["tokens"])}
    sites = probe_linear_inputs(cfg, params, batch)
    p0 = params["stages"]["s0"]["p0"]
    site_w = {"q_proj_in": p0["wq"][0], "o_proj_in": p0["wo"][0],
              "gate_up_in": p0["w_gate"][0],
              "down_proj_in": p0["w_down"][0]}
    masks = {n: importance_mask_tile_aligned(jnp.asarray(w), 50.0, 16)
             for n, w in site_w.items()}

    # --- global (l, h) calibration sweep (paper §3.2, Llama recipe) -----
    def eval_lh(l, h):
        mses, sps = [], []
        for name, q8 in sites:
            qc = apply_clipping(q8, masks[name], l, h)
            mses.append(float(jnp.mean(
                (qc.astype(jnp.float32) - q8.astype(jnp.float32)) ** 2)))
            sps.append(float(subprecision_sparsity(qc)))
        return sum(mses) / len(mses), sum(sps) / len(sps)

    best = global_calibrate(eval_lh)
    emit("accuracy/calibrated_l", best.l, f"sparsity {best.sparsity:.3f}")
    emit("accuracy/calibrated_h", best.h, f"cal err {best.error:.3f}")

    # --- Table 2 analogue ------------------------------------------------
    ppl_float = eval_ppl(cfg, params)
    qp_w4a8 = quantize_model_params(params, w_bits=4,
                                    enable_clipping=False)
    ppl_w4a8 = eval_ppl(cfg, qp_w4a8)
    qp_sparqle = quantize_model_params(
        params, w_bits=4, k_percent=50.0, clip_l=float(best.l),
        clip_h=float(best.h), tile_k=16)
    ppl_sparqle = eval_ppl(cfg, qp_sparqle)

    import repro.core.qlinear as QL
    import repro.core.quantize as Q
    orig = Q.quantize_activations

    def a4(x, bits=8, per_token=True, zero_point=False):
        return orig(x, bits=4, per_token=per_token, zero_point=zero_point)

    QL.quantize_activations = a4
    try:
        ppl_w4a4 = eval_ppl(cfg, qp_w4a8)
    finally:
        QL.quantize_activations = orig

    emit("accuracy/ppl_float", ppl_float, "reference")
    emit("accuracy/ppl_w4a8", ppl_w4a8, "dense quant baseline")
    emit("accuracy/ppl_sparqle", ppl_sparqle,
         f"delta vs W4A8 {ppl_sparqle - ppl_w4a8:+.3f}")
    emit("accuracy/ppl_w4a4", ppl_w4a4, "aggressive baseline")
    emit("accuracy/between_w4a8_and_w4a4",
         float(ppl_w4a8 - 1e-6 <= ppl_sparqle <= ppl_w4a4 + 1e-6),
         "1.0 reproduces the paper's ordering claim")

    # achieved sparsity with the calibrated constants
    ss = []
    for name, q8 in sites:
        ss.append(float(subprecision_sparsity(
            apply_clipping(q8, masks[name], best.l, best.h))))
    nat = [float(subprecision_sparsity(q8)) for _, q8 in sites]
    emit("accuracy/natural_sparsity", sum(nat) / len(nat) * 100, "%")
    emit("accuracy/enhanced_sparsity", sum(ss) / len(ss) * 100,
         "% after calibrated clipping")


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v:.4g},{d}"))
